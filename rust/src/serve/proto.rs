//! Length-prefixed binary wire protocol of the serve layer.
//!
//! Framing (all integers little-endian):
//!
//! ```text
//! frame   := len:u32 | body                  len = body length in bytes
//! body    := version:u8 | opcode:u8 | tag | payload
//! tag     := request_id:u64                  (v3+ frames only; absent before)
//! bytes   := n:u32 | raw[n]
//! string  := bytes (utf-8)
//! opt<T>  := 0:u8 | 1:u8 T
//! list<T> := n:u32 | T[n]
//! ```
//!
//! # Opcodes
//!
//! | op     | since | direction | message |
//! |--------|-------|-----------|---------|
//! | `0x01` | v1    | request   | `Classify { input: bytes }` |
//! | `0x02` | v1    | request   | `ClassifySession { session: u64, input: bytes }` |
//! | `0x03` | v1    | request   | `LearnWay { session: u64, shots: list<bytes> }` |
//! | `0x04` | v1    | request   | `EvictSession { session: u64 }` |
//! | `0x05` | v1    | request   | `Health` |
//! | `0x06` | v1    | request   | `Metrics` |
//! | `0x07` | v2    | request   | `StreamOpen { session: u64, hop: u32 }` |
//! | `0x08` | v2    | request   | `StreamPush { session: u64, samples: bytes }` |
//! | `0x09` | v2    | request   | `StreamClose { session: u64 }` |
//! | `0x0A` | v3    | request   | `ClassifyBatch { inputs: list<bytes> }` |
//! | `0x0B` | v4    | request   | `AddShots { session: u64, way: u64, shots: list<bytes> }` |
//! | `0x0C` | v4    | request   | `SessionInfo { session: u64 }` |
//! | `0x0D` | v5    | request   | `Stat` (flight-recorder dump) |
//! | `0x0E` | v6    | request   | `SessionExport { session: u64 }` |
//! | `0x0F` | v6    | request   | `SessionImport { session: u64, blob: bytes }` |
//! | `0x81` | v1    | response  | `Reply { predicted?, logits?, learned_way?, cycles?, spans? (v5) }` |
//! | `0x82` | v1    | response  | `Health { shards, sessions, input_len, embed_dim, window (v2), channels (v2) }` |
//! | `0x83` | v1    | response  | `Metrics { counters..., latency percentiles }` |
//! | `0x84` | v1    | response  | `Evicted { existed: u8 }` |
//! | `0x85` | v2    | response  | `StreamOpened { window: u32, hop: u32 }` |
//! | `0x86` | v2    | response  | `StreamDecisions(list<decision>)` |
//! | `0x87` | v2    | response  | `StreamClosed { existed: u8, windows: u64 }` |
//! | `0x88` | v3    | response  | `ReplyBatch(list<item>)` |
//! | `0x89` | v4    | response  | `SessionInfo { exists, ways, shots, bytes_used, bytes_per_way, way_cap }` |
//! | `0x8A` | v5    | response  | `Stat { recorded, overwritten, events: list<event>, sessions (v6) }` |
//! | `0x8B` | v6    | response  | `SessionExported { blob: bytes }` |
//! | `0xFF` | v1    | response  | `Error { code: u8, message: string }` |
//!
//! # Versioning
//!
//! Every frame carries its version byte. This build encodes requests at
//! [`VERSION`] and decodes any version from [`MIN_VERSION`] up to
//! [`VERSION`]: each version is a strict superset of the one before, so
//! older frames still decode (payload fields a later version appended
//! simply decode as zero; the v3 `request_id` tag is absent and reads as
//! 0). The server replies **at the requester's version**
//! ([`encode_response_versioned`]), omitting newer payload fields and the
//! tag from older frames, so strict v1..v4 clients keep working against
//! a v6 server. Version-gated opcodes (streams in v2, batch in v3, the
//! continual-learning ops in v4, the stat dump in v5, the durability ops
//! in v6) inside an older frame are malformed.
//!
//! # Continual learning (v4)
//!
//! `AddShots` folds new support shots into an *already learned* way of a
//! session's prototypical head by running mean — bit-identical to having
//! learned the way from the concatenated shot set — and is answered with
//! a `Reply` whose `learned_way` echoes the updated way. `SessionInfo`
//! reports a session's learned state and its memory accounting (ways,
//! total shots, `bytes_used = ways * bytes_per_way`, and the server's
//! way cap; `way_cap = 0` means unbounded). Learn ops against a full way
//! budget answer a typed `App` error naming `WaysExhausted`. `Metrics`
//! gains the v4 `add_shots` counter.
//!
//! # Pipelining (v3)
//!
//! A v3 request frame carries a client-assigned `request_id` that the
//! server echoes in the response frame. That makes responses self-
//! identifying, so a client may keep many requests in flight on one
//! connection and the server completes them **in whatever order its
//! workers finish** — out-of-order responses are expected and correct.
//! Pre-v3 frames carry no tag; the server answers them strictly in order
//! (one at a time), preserving the original request/response discipline.
//! `ClassifyBatch` carries N session-less windows in one frame; the server
//! fans them out across shards and answers with one `ReplyBatch` whose
//! items are in input order, each independently a reply or an error.
//!
//! # Observability (v5)
//!
//! Every v5 `Reply` (including each `ReplyBatch` item) appends a span
//! decomposition of the request's life inside the server: `queue_us`
//! (enqueue → worker pickup), `service_us` (worker pickup → handler done)
//! and `write_us` (handler done → reply handed to the connection writer),
//! so a client can split its observed end-to-end latency into queueing,
//! compute, and reply-path time without any out-of-band tooling.
//! `Metrics` gains live gauges (queue depth, in-flight requests,
//! session-store occupancy and prototype bytes, writer-backlog high-water
//! mark) plus a per-op latency table keyed by stable op ids (see
//! [`crate::coordinator::OpKind`]). The new `Stat` op dumps the server's
//! flight recorder — its ring of recent notable events (errors, panics,
//! evictions, rejections, slow requests) merged across shards — for
//! post-hoc debugging of exactly the requests that went wrong. Pre-v5
//! frames carry none of this and decode exactly as v4 shipped.
//!
//! # Durability (v6)
//!
//! `SessionExport` asks the server for a session's full learner state as
//! one opaque, versioned snapshot blob (see
//! [`crate::coordinator::snapshot`] for the blob's own layout — the wire
//! treats it as bytes) and is answered with `SessionExported`. The export
//! is a pure read: it does not touch the session's LRU position.
//! `SessionImport` replaces (or creates) a session's learner state from
//! such a blob on a server whose model geometry matches, invalidating any
//! prepared head and re-running the receiver's own way-budget accounting;
//! it is answered with the restored session's `SessionInfo`, so the
//! importer can verify way/shot counts without a second round trip.
//! Importing is **not idempotent** from the client's point of view (a
//! retried import races any concurrent learning on the same session), so
//! the client treats it like `AddShots`: a transport failure after the
//! request may have been sent surfaces an error instead of a silent
//! retry. `Stat` additionally reports the live session ids across all
//! shards, so a snapshot driver can enumerate what to export. Pre-v6
//! frames carry none of this and decode exactly as v5 shipped.
//!
//! A frame whose length prefix exceeds [`MAX_FRAME`] bytes (or is too short
//! to hold the header), whose version byte is unknown, or whose payload
//! does not decode exactly, is *malformed*: the server answers with an
//! `Error { code: Malformed }` frame and closes the connection. Payload
//! decoding is strict — trailing bytes are an error — so every frame has
//! exactly one valid byte representation per version (round-trip tested
//! below).

use std::io::{Read, Write};

use anyhow::{bail, Result};

/// Highest protocol version this build speaks; every encoded frame
/// carries it.
pub const VERSION: u8 = 6;

/// Oldest protocol version still accepted on decode.
pub const MIN_VERSION: u8 = 1;

/// Upper bound on one frame body; protects the server from hostile length
/// prefixes (a learn frame of 64 shots x 16 kB inputs is ~1 MB, so 16 MiB
/// leaves ample headroom).
pub const MAX_FRAME: usize = 16 << 20;

/// Upper bound on list-of-inputs ops (`LearnWay` shots, `ClassifyBatch`
/// windows) — a hostile count must not drive allocation.
pub const MAX_LIST: usize = 4096;

// Request opcodes.
const OP_CLASSIFY: u8 = 0x01;
const OP_CLASSIFY_SESSION: u8 = 0x02;
const OP_LEARN_WAY: u8 = 0x03;
const OP_EVICT_SESSION: u8 = 0x04;
const OP_HEALTH: u8 = 0x05;
const OP_METRICS: u8 = 0x06;
const OP_STREAM_OPEN: u8 = 0x07;
const OP_STREAM_PUSH: u8 = 0x08;
const OP_STREAM_CLOSE: u8 = 0x09;
const OP_CLASSIFY_BATCH: u8 = 0x0A;
const OP_ADD_SHOTS: u8 = 0x0B;
const OP_SESSION_INFO: u8 = 0x0C;
const OP_STAT: u8 = 0x0D;
const OP_SESSION_EXPORT: u8 = 0x0E;
const OP_SESSION_IMPORT: u8 = 0x0F;

// Response opcodes.
const OP_REPLY: u8 = 0x81;
const OP_HEALTH_REPLY: u8 = 0x82;
const OP_METRICS_REPLY: u8 = 0x83;
const OP_EVICTED: u8 = 0x84;
const OP_STREAM_OPENED: u8 = 0x85;
const OP_STREAM_DECISIONS: u8 = 0x86;
const OP_STREAM_CLOSED: u8 = 0x87;
const OP_REPLY_BATCH: u8 = 0x88;
const OP_SESSION_INFO_REPLY: u8 = 0x89;
const OP_STAT_REPLY: u8 = 0x8A;
const OP_SESSION_EXPORTED: u8 = 0x8B;
const OP_ERROR: u8 = 0xFF;

/// Client -> server messages.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum WireRequest {
    /// Classify with the model's built-in head.
    Classify { input: Vec<u8> },
    /// Classify against a session's learned prototypical head.
    ClassifySession { session: u64, input: Vec<u8> },
    /// Learn one new way for a session from k support sequences.
    LearnWay { session: u64, shots: Vec<Vec<u8>> },
    /// Drop a session's learned head.
    EvictSession { session: u64 },
    /// Liveness + model geometry probe.
    Health,
    /// Aggregated serving metrics across all shards.
    Metrics,
    /// v2: open (or reset) an incremental stream on a session. The window
    /// is the model's `seq_len`; `hop` is the decision stride in
    /// timesteps.
    StreamOpen { session: u64, hop: u32 },
    /// v2: push a chunk of u4 samples into a session's open stream;
    /// answered by `StreamDecisions` with zero or more per-window results.
    StreamPush { session: u64, samples: Vec<u8> },
    /// v2: close a session's stream (its learned head survives).
    StreamClose { session: u64 },
    /// v3: classify N session-less windows in one frame; the server fans
    /// them out across shards and answers with a `ReplyBatch` in input
    /// order.
    ClassifyBatch { inputs: Vec<Vec<u8>> },
    /// v4: fold new support shots into an already learned way of a
    /// session's head (continual learning); answered with a `Reply` whose
    /// `learned_way` echoes the updated way.
    AddShots { session: u64, way: u64, shots: Vec<Vec<u8>> },
    /// v4: report a session's learned state and memory accounting.
    SessionInfo { session: u64 },
    /// v5: dump the server's flight recorder (recent notable events,
    /// merged across shards).
    Stat,
    /// v6: export a session's full learner state as an opaque snapshot
    /// blob (answered with `SessionExported`); a pure read that does not
    /// touch the session's LRU position.
    SessionExport { session: u64 },
    /// v6: replace (or create) a session's learner state from a snapshot
    /// blob; answered with the restored session's `SessionInfo`.
    SessionImport { session: u64, blob: Vec<u8> },
}

/// Server -> client messages.
#[derive(Debug, Clone, PartialEq)]
pub enum WireResponse {
    Reply(WireReply),
    Health(HealthWire),
    Metrics(MetricsWire),
    Evicted { existed: bool },
    /// v2: stream accepted; echoes the window length and hop (timesteps).
    StreamOpened { window: u32, hop: u32 },
    /// v2: per-window decisions completed by a `StreamPush` (often empty).
    StreamDecisions(Vec<WireDecision>),
    /// v2: stream closed; whether one existed and how many windows it
    /// emitted over its lifetime.
    StreamClosed { existed: bool, windows: u64 },
    /// v3: one item per `ClassifyBatch` window, in input order.
    ReplyBatch(Vec<BatchItem>),
    /// v4: a session's learned state + way-budget accounting.
    SessionInfo(SessionInfoWire),
    /// v5: the flight-recorder dump (recent notable events, oldest first).
    Stat(StatWire),
    /// v6: a session's learner state as an opaque snapshot blob.
    SessionExported { blob: Vec<u8> },
    Error { code: ErrorCode, message: String },
}

/// v5 `Stat` payload: the flight recorder's accounting plus its current
/// ring contents, oldest first. `recorded - events.len()` events have
/// been discarded by ring wrap (≈ `overwritten`).
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct StatWire {
    /// Total events ever recorded across all shards.
    pub recorded: u64,
    /// Total events discarded by ring wrap across all shards.
    pub overwritten: u64,
    pub events: Vec<FlightEventWire>,
    /// v6: live session ids across all shards (sorted), so a snapshot
    /// driver can enumerate what to export; empty from a pre-v6 peer.
    pub sessions: Vec<u64>,
}

/// One flight-recorder event on the wire (see
/// [`crate::coordinator::FlightEvent`]). `kind` and `op` are the stable
/// u8 ids of [`crate::coordinator::FlightKind`] /
/// [`crate::coordinator::OpKind`]; unknown ids from a newer peer are kept
/// verbatim rather than rejected.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FlightEventWire {
    /// Per-shard monotonic sequence number.
    pub seq: u64,
    /// Microseconds since the owning shard started.
    pub at_us: u64,
    pub kind: u8,
    pub op: u8,
    /// Short free-form context (error text, panic message, session id…).
    pub detail: String,
}

impl From<&crate::coordinator::FlightEvent> for FlightEventWire {
    fn from(e: &crate::coordinator::FlightEvent) -> FlightEventWire {
        FlightEventWire {
            seq: e.seq,
            at_us: e.at_us,
            kind: e.kind.id(),
            op: e.op.index() as u8,
            detail: e.detail.clone(),
        }
    }
}

impl FlightEventWire {
    /// Human-readable kind name (falls back to the raw id for ids newer
    /// than this build).
    pub fn kind_name(&self) -> String {
        match crate::coordinator::FlightKind::from_id(self.kind) {
            Some(k) => k.name().to_string(),
            None => format!("kind{}", self.kind),
        }
    }

    /// Human-readable op name (falls back to the raw id).
    pub fn op_name(&self) -> String {
        match crate::coordinator::OpKind::from_index(self.op as usize) {
            Some(o) => o.name().to_string(),
            None => format!("op{}", self.op),
        }
    }
}

/// v4 `SessionInfo` payload: the session's continual-learning state and
/// the way-budget math a client needs for capacity planning
/// (`bytes_used = ways * bytes_per_way`; `way_cap = 0` means unbounded).
/// `bytes_per_way` and `way_cap` are deployment constants, reported even
/// for sessions that do not (yet) exist.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SessionInfoWire {
    pub exists: bool,
    /// Ways learned so far.
    pub ways: u64,
    /// Total support shots absorbed across all ways.
    pub shots: u64,
    /// Prototype memory in use: `ways * bytes_per_way`.
    pub bytes_used: u64,
    /// Per-way cost in bytes: `ceil(V/2) + 2` (paper: ~26 B at V = 48).
    pub bytes_per_way: u32,
    /// Server-side way cap per session (0 = unbounded).
    pub way_cap: u64,
}

impl From<crate::coordinator::server::SessionInfoData> for SessionInfoWire {
    fn from(s: crate::coordinator::server::SessionInfoData) -> SessionInfoWire {
        SessionInfoWire {
            exists: s.exists,
            ways: s.ways,
            shots: s.shots,
            bytes_used: s.bytes_used,
            bytes_per_way: s.bytes_per_way,
            way_cap: s.way_cap,
        }
    }
}

/// One `ClassifyBatch` outcome: windows succeed or fail independently, so
/// a single bad window cannot sink its whole frame.
#[derive(Debug, Clone, PartialEq)]
pub enum BatchItem {
    Reply(WireReply),
    Error { code: ErrorCode, message: String },
}

/// One per-window classification decision on the wire.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct WireDecision {
    /// 0-based window index within the stream.
    pub window: u64,
    /// Absolute 0-based timestep of the window's last sample.
    pub end_t: u64,
    pub predicted: u64,
    pub logits: Vec<i32>,
}

/// Mirror of [`crate::coordinator::Response`] on the wire.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct WireReply {
    pub predicted: Option<u64>,
    pub logits: Option<Vec<i32>>,
    pub learned_way: Option<u64>,
    pub sim_cycles: Option<u64>,
    /// v5: microseconds the request waited in the shard queue before a
    /// worker picked it up; `None` from a pre-v5 peer.
    pub queue_us: Option<u64>,
    /// v5: microseconds the worker spent servicing the request (handler
    /// start → handler done); `None` from a pre-v5 peer.
    pub service_us: Option<u64>,
    /// v5: microseconds between the handler finishing and the reply being
    /// handed to the connection writer; `None` from a pre-v5 peer.
    pub write_us: Option<u64>,
}

/// Health probe payload: enough for a client (or the load generator) to
/// shape valid traffic without out-of-band model knowledge.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct HealthWire {
    pub shards: u32,
    pub live_sessions: u64,
    /// Flat input length (`seq_len * in_channels`) a request must carry.
    pub input_len: u32,
    pub embed_dim: u32,
    /// v2: model window length in timesteps (`seq_len`); 0 from a v1 peer.
    pub window: u32,
    /// v2: input channels per timestep; 0 from a v1 peer.
    pub channels: u32,
}

/// Aggregated metrics payload (counters summed across shards, percentiles
/// computed over the merged fixed-bucket histograms).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MetricsWire {
    pub requests: u64,
    pub completed: u64,
    pub errors: u64,
    pub rejected: u64,
    pub learn_ways: u64,
    pub evictions: u64,
    pub sim_cycles: u64,
    /// v2: stream chunks accepted; 0 from a v1 peer.
    pub stream_chunks: u64,
    /// v2: per-window stream decisions emitted; 0 from a v1 peer.
    pub stream_decisions: u64,
    /// v3: handler panics caught by workers (the shard survived each one);
    /// 0 from a pre-v3 peer.
    pub worker_panics: u64,
    /// v4: continual-learning `AddShots` ops applied; 0 from a pre-v4
    /// peer.
    pub add_shots: u64,
    pub mean_latency_us: f64,
    pub p50_latency_us: f64,
    pub p95_latency_us: f64,
    pub p99_latency_us: f64,
    /// v5: requests sitting in shard queues right now; 0 from a pre-v5
    /// peer (as are the gauges and the per-op table below).
    pub queue_depth: u64,
    /// v5: requests currently inside worker handlers.
    pub in_flight: u64,
    /// v5: live sessions across all shards.
    pub sessions_live: u64,
    /// v5: prototype bytes held by live sessions.
    pub session_bytes: u64,
    /// v5: max writer backlog any connection has reached (frames).
    pub backlog_hwm: u64,
    /// v5: per-op latency table, one entry per [`crate::coordinator::OpKind`]
    /// in stable id order; empty from a pre-v5 peer.
    pub per_op: Vec<OpMetricsWire>,
}

/// One per-op row of the v5 `Metrics` payload.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct OpMetricsWire {
    /// Stable [`crate::coordinator::OpKind`] id.
    pub op: u8,
    pub count: u64,
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
}

impl OpMetricsWire {
    /// Human-readable op name (falls back to the raw id).
    pub fn op_name(&self) -> String {
        match crate::coordinator::OpKind::from_index(self.op as usize) {
            Some(o) => o.name().to_string(),
            None => format!("op{}", self.op),
        }
    }
}

impl From<&crate::coordinator::metrics::MetricsSnapshot> for MetricsWire {
    fn from(s: &crate::coordinator::metrics::MetricsSnapshot) -> MetricsWire {
        use crate::coordinator::OpKind;
        let per_op = OpKind::ALL
            .iter()
            .map(|&op| {
                let h = s.op_hist(op);
                OpMetricsWire {
                    op: op.index() as u8,
                    count: h.count,
                    p50_us: h.percentile_us(50.0),
                    p95_us: h.percentile_us(95.0),
                    p99_us: h.percentile_us(99.0),
                }
            })
            .collect();
        MetricsWire {
            requests: s.requests,
            completed: s.completed,
            errors: s.errors,
            rejected: s.rejected,
            learn_ways: s.learn_ways,
            evictions: s.evictions,
            sim_cycles: s.sim_cycles,
            stream_chunks: s.stream_chunks,
            stream_decisions: s.stream_decisions,
            worker_panics: s.worker_panics,
            add_shots: s.add_shots,
            mean_latency_us: s.mean_latency_us,
            p50_latency_us: s.p50_latency_us,
            p95_latency_us: s.p95_latency_us,
            p99_latency_us: s.p99_latency_us,
            queue_depth: s.queue_depth,
            in_flight: s.in_flight,
            sessions_live: s.sessions_live,
            session_bytes: s.session_bytes,
            backlog_hwm: s.backlog_hwm,
            per_op,
        }
    }
}

impl MetricsWire {
    /// Keep the line format in sync with `MetricsSnapshot::report`
    /// (coordinator/metrics.rs) — same fields, wire side simply lacks the
    /// raw histogram.
    pub fn report(&self) -> String {
        let mut s = format!(
            "requests={} completed={} errors={} worker_panics={} rejected={} learned_ways={} \
             add_shots={} evictions={} stream_chunks={} stream_decisions={} \
             latency mean={:.1}us p50={:.1}us p95={:.1}us p99={:.1}us sim_cycles={}",
            self.requests,
            self.completed,
            self.errors,
            self.worker_panics,
            self.rejected,
            self.learn_ways,
            self.add_shots,
            self.evictions,
            self.stream_chunks,
            self.stream_decisions,
            self.mean_latency_us,
            self.p50_latency_us,
            self.p95_latency_us,
            self.p99_latency_us,
            self.sim_cycles,
        );
        s.push_str(&format!(
            " queued={} in_flight={} sessions={} session_bytes={} backlog_hwm={}",
            self.queue_depth,
            self.in_flight,
            self.sessions_live,
            self.session_bytes,
            self.backlog_hwm,
        ));
        for row in self.per_op.iter().filter(|r| r.count > 0) {
            s.push_str(&format!(
                "\n  {}: n={} p50={:.1}us p95={:.1}us p99={:.1}us",
                row.op_name(),
                row.count,
                row.p50_us,
                row.p95_us,
                row.p99_us,
            ));
        }
        s
    }
}

/// Wire error classes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorCode {
    /// Bounded-queue backpressure: the request was *not* processed; retry
    /// later or shed. Surfaced instead of letting the connection hang.
    Overloaded,
    /// The frame violated the protocol; the server closes the connection.
    Malformed,
    /// The request was well-formed but failed (unknown session, wrong
    /// input length, engine error, shutdown).
    App,
}

impl ErrorCode {
    pub fn as_u8(self) -> u8 {
        match self {
            ErrorCode::Overloaded => 1,
            ErrorCode::Malformed => 2,
            ErrorCode::App => 3,
        }
    }

    pub fn from_u8(v: u8) -> Result<ErrorCode> {
        Ok(match v {
            1 => ErrorCode::Overloaded,
            2 => ErrorCode::Malformed,
            3 => ErrorCode::App,
            _ => bail!("unknown error code {v}"),
        })
    }
}

/// One decoded request frame: the peer's protocol version, the pipelining
/// tag (0 for pre-v3 frames, which carry none), and the request itself.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestFrame {
    pub version: u8,
    pub request_id: u64,
    pub req: WireRequest,
}

/// One decoded response frame: version, echoed tag (0 pre-v3), response.
#[derive(Debug, Clone, PartialEq)]
pub struct ResponseFrame {
    pub version: u8,
    pub request_id: u64,
    pub resp: WireResponse,
}

// ---------------------------------------------------------------------------
// Encoding
// ---------------------------------------------------------------------------

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    put_u32(out, b.len() as u32);
    out.extend_from_slice(b);
}

fn put_opt_u64(out: &mut Vec<u8>, v: Option<u64>) {
    match v {
        None => out.push(0),
        Some(x) => {
            out.push(1);
            put_u64(out, x);
        }
    }
}

fn put_opt_i32s(out: &mut Vec<u8>, v: &Option<Vec<i32>>) {
    match v {
        None => out.push(0),
        Some(xs) => {
            out.push(1);
            put_u32(out, xs.len() as u32);
            for x in xs {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
}

/// A reply payload at protocol version `v`: the span fields only exist on
/// v5+ frames (shared by `Reply` and each `ReplyBatch` item).
fn put_reply(out: &mut Vec<u8>, r: &WireReply, v: u8) {
    put_opt_u64(out, r.predicted);
    put_opt_i32s(out, &r.logits);
    put_opt_u64(out, r.learned_way);
    put_opt_u64(out, r.sim_cycles);
    if v >= 5 {
        put_opt_u64(out, r.queue_us);
        put_opt_u64(out, r.service_us);
        put_opt_u64(out, r.write_us);
    }
}

/// Frame header: version, opcode, and the v3 pipelining tag.
fn head(v: u8, opcode: u8, request_id: u64) -> Vec<u8> {
    let mut b = vec![v, opcode];
    if v >= 3 {
        put_u64(&mut b, request_id);
    }
    b
}

/// Lowest protocol version that can carry this request (streams: v2,
/// batch: v3, continual-learning ops: v4, stat: v5, durability ops: v6).
/// Clients speaking an older version must refuse such ops rather than
/// silently up-version the frame — a server treats any v3+ frame as
/// pipelined, which would break an in-order client's response matching.
pub fn request_min_version(req: &WireRequest) -> u8 {
    match req {
        WireRequest::StreamOpen { .. }
        | WireRequest::StreamPush { .. }
        | WireRequest::StreamClose { .. } => 2,
        WireRequest::ClassifyBatch { .. } => 3,
        WireRequest::AddShots { .. } | WireRequest::SessionInfo { .. } => 4,
        WireRequest::Stat => 5,
        WireRequest::SessionExport { .. } | WireRequest::SessionImport { .. } => 6,
        _ => 1,
    }
}

/// Lowest protocol version that can carry this response.
fn response_min_version(resp: &WireResponse) -> u8 {
    match resp {
        WireResponse::StreamOpened { .. }
        | WireResponse::StreamDecisions(_)
        | WireResponse::StreamClosed { .. } => 2,
        WireResponse::ReplyBatch(_) => 3,
        WireResponse::SessionInfo(_) => 4,
        WireResponse::Stat(_) => 5,
        WireResponse::SessionExported { .. } => 6,
        _ => 1,
    }
}

fn request_opcode(req: &WireRequest) -> u8 {
    match req {
        WireRequest::Classify { .. } => OP_CLASSIFY,
        WireRequest::ClassifySession { .. } => OP_CLASSIFY_SESSION,
        WireRequest::LearnWay { .. } => OP_LEARN_WAY,
        WireRequest::EvictSession { .. } => OP_EVICT_SESSION,
        WireRequest::Health => OP_HEALTH,
        WireRequest::Metrics => OP_METRICS,
        WireRequest::StreamOpen { .. } => OP_STREAM_OPEN,
        WireRequest::StreamPush { .. } => OP_STREAM_PUSH,
        WireRequest::StreamClose { .. } => OP_STREAM_CLOSE,
        WireRequest::ClassifyBatch { .. } => OP_CLASSIFY_BATCH,
        WireRequest::AddShots { .. } => OP_ADD_SHOTS,
        WireRequest::SessionInfo { .. } => OP_SESSION_INFO,
        WireRequest::Stat => OP_STAT,
        WireRequest::SessionExport { .. } => OP_SESSION_EXPORT,
        WireRequest::SessionImport { .. } => OP_SESSION_IMPORT,
    }
}

fn response_opcode(resp: &WireResponse) -> u8 {
    match resp {
        WireResponse::Reply(_) => OP_REPLY,
        WireResponse::Health(_) => OP_HEALTH_REPLY,
        WireResponse::Metrics(_) => OP_METRICS_REPLY,
        WireResponse::Evicted { .. } => OP_EVICTED,
        WireResponse::StreamOpened { .. } => OP_STREAM_OPENED,
        WireResponse::StreamDecisions(_) => OP_STREAM_DECISIONS,
        WireResponse::StreamClosed { .. } => OP_STREAM_CLOSED,
        WireResponse::ReplyBatch(_) => OP_REPLY_BATCH,
        WireResponse::SessionInfo(_) => OP_SESSION_INFO_REPLY,
        WireResponse::Stat(_) => OP_STAT_REPLY,
        WireResponse::SessionExported { .. } => OP_SESSION_EXPORTED,
        WireResponse::Error { .. } => OP_ERROR,
    }
}

/// Encode a request as a full frame (length prefix included) at the
/// current [`VERSION`] with tag 0 (tests / fire-and-forget).
pub fn encode_request(req: &WireRequest) -> Vec<u8> {
    encode_request_versioned(req, VERSION, 0)
}

/// Encode a request at a chosen protocol version with a pipelining tag.
/// Pre-v3 versions omit the tag. Out-of-range versions clamp into the
/// supported range, and an op newer than the requested version raises the
/// frame to the op's minimum version (a v1 peer cannot express a stream
/// op at all).
pub fn encode_request_versioned(req: &WireRequest, version: u8, request_id: u64) -> Vec<u8> {
    let v = version.clamp(MIN_VERSION, VERSION).max(request_min_version(req));
    let mut b = head(v, request_opcode(req), request_id);
    match req {
        WireRequest::Classify { input } => put_bytes(&mut b, input),
        WireRequest::ClassifySession { session, input } => {
            put_u64(&mut b, *session);
            put_bytes(&mut b, input);
        }
        WireRequest::LearnWay { session, shots } => {
            put_u64(&mut b, *session);
            put_u32(&mut b, shots.len() as u32);
            for s in shots {
                put_bytes(&mut b, s);
            }
        }
        WireRequest::EvictSession { session } => put_u64(&mut b, *session),
        WireRequest::Health | WireRequest::Metrics | WireRequest::Stat => {}
        WireRequest::StreamOpen { session, hop } => {
            put_u64(&mut b, *session);
            put_u32(&mut b, *hop);
        }
        WireRequest::StreamPush { session, samples } => {
            put_u64(&mut b, *session);
            put_bytes(&mut b, samples);
        }
        WireRequest::StreamClose { session } => put_u64(&mut b, *session),
        WireRequest::ClassifyBatch { inputs } => {
            put_u32(&mut b, inputs.len() as u32);
            for x in inputs {
                put_bytes(&mut b, x);
            }
        }
        WireRequest::AddShots { session, way, shots } => {
            put_u64(&mut b, *session);
            put_u64(&mut b, *way);
            put_u32(&mut b, shots.len() as u32);
            for s in shots {
                put_bytes(&mut b, s);
            }
        }
        WireRequest::SessionInfo { session } => put_u64(&mut b, *session),
        WireRequest::SessionExport { session } => put_u64(&mut b, *session),
        WireRequest::SessionImport { session, blob } => {
            put_u64(&mut b, *session);
            put_bytes(&mut b, blob);
        }
    }
    prepend_len(&mut b);
    b
}

/// Encode a response as a full frame (length prefix included) at the
/// current [`VERSION`] with tag 0.
pub fn encode_response(resp: &WireResponse) -> Vec<u8> {
    encode_response_versioned(resp, VERSION, 0)
}

/// Encode a response at the *requester's* protocol version with the
/// requester's tag echoed, so every peer can decode its reply: fields a
/// newer version appended to `Health`/`Metrics` are omitted from older
/// frames, pre-v3 frames omit the tag, and responses that only exist in a
/// newer version (streams: v2, batch: v3) are stamped at their minimum
/// version. Out-of-range versions clamp into the supported range.
pub fn encode_response_versioned(resp: &WireResponse, version: u8, request_id: u64) -> Vec<u8> {
    let v = version.clamp(MIN_VERSION, VERSION).max(response_min_version(resp));
    let mut b = head(v, response_opcode(resp), request_id);
    match resp {
        WireResponse::Reply(r) => put_reply(&mut b, r, v),
        WireResponse::Health(h) => {
            put_u32(&mut b, h.shards);
            put_u64(&mut b, h.live_sessions);
            put_u32(&mut b, h.input_len);
            put_u32(&mut b, h.embed_dim);
            if v >= 2 {
                put_u32(&mut b, h.window);
                put_u32(&mut b, h.channels);
            }
        }
        WireResponse::Metrics(m) => {
            for c in [
                m.requests, m.completed, m.errors, m.rejected,
                m.learn_ways, m.evictions, m.sim_cycles,
            ] {
                put_u64(&mut b, c);
            }
            if v >= 2 {
                put_u64(&mut b, m.stream_chunks);
                put_u64(&mut b, m.stream_decisions);
            }
            if v >= 3 {
                put_u64(&mut b, m.worker_panics);
            }
            if v >= 4 {
                put_u64(&mut b, m.add_shots);
            }
            for c in [m.mean_latency_us, m.p50_latency_us, m.p95_latency_us, m.p99_latency_us] {
                put_f64(&mut b, c);
            }
            if v >= 5 {
                for g in [
                    m.queue_depth, m.in_flight, m.sessions_live,
                    m.session_bytes, m.backlog_hwm,
                ] {
                    put_u64(&mut b, g);
                }
                put_u32(&mut b, m.per_op.len() as u32);
                for row in &m.per_op {
                    b.push(row.op);
                    put_u64(&mut b, row.count);
                    put_f64(&mut b, row.p50_us);
                    put_f64(&mut b, row.p95_us);
                    put_f64(&mut b, row.p99_us);
                }
            }
        }
        WireResponse::Evicted { existed } => b.push(u8::from(*existed)),
        WireResponse::StreamOpened { window, hop } => {
            put_u32(&mut b, *window);
            put_u32(&mut b, *hop);
        }
        WireResponse::StreamDecisions(ds) => {
            put_u32(&mut b, ds.len() as u32);
            for d in ds {
                put_u64(&mut b, d.window);
                put_u64(&mut b, d.end_t);
                put_u64(&mut b, d.predicted);
                put_u32(&mut b, d.logits.len() as u32);
                for x in &d.logits {
                    b.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
        WireResponse::StreamClosed { existed, windows } => {
            b.push(u8::from(*existed));
            put_u64(&mut b, *windows);
        }
        WireResponse::ReplyBatch(items) => {
            put_u32(&mut b, items.len() as u32);
            for item in items {
                match item {
                    BatchItem::Reply(r) => {
                        b.push(0);
                        put_reply(&mut b, r, v);
                    }
                    BatchItem::Error { code, message } => {
                        b.push(1);
                        b.push(code.as_u8());
                        put_bytes(&mut b, message.as_bytes());
                    }
                }
            }
        }
        WireResponse::SessionInfo(si) => {
            b.push(u8::from(si.exists));
            put_u64(&mut b, si.ways);
            put_u64(&mut b, si.shots);
            put_u64(&mut b, si.bytes_used);
            put_u32(&mut b, si.bytes_per_way);
            put_u64(&mut b, si.way_cap);
        }
        WireResponse::Stat(st) => {
            put_u64(&mut b, st.recorded);
            put_u64(&mut b, st.overwritten);
            put_u32(&mut b, st.events.len() as u32);
            for e in &st.events {
                put_u64(&mut b, e.seq);
                put_u64(&mut b, e.at_us);
                b.push(e.kind);
                b.push(e.op);
                put_bytes(&mut b, e.detail.as_bytes());
            }
            if v >= 6 {
                put_u32(&mut b, st.sessions.len() as u32);
                for id in &st.sessions {
                    put_u64(&mut b, *id);
                }
            }
        }
        WireResponse::SessionExported { blob } => put_bytes(&mut b, blob),
        WireResponse::Error { code, message } => {
            b.push(code.as_u8());
            put_bytes(&mut b, message.as_bytes());
        }
    }
    prepend_len(&mut b);
    b
}

fn prepend_len(b: &mut Vec<u8>) {
    let len = (b.len() as u32).to_le_bytes();
    b.splice(0..0, len);
}

// ---------------------------------------------------------------------------
// Decoding
// ---------------------------------------------------------------------------

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let Some(s) = self.i.checked_add(n).and_then(|end| self.b.get(self.i..end)) else {
            bail!("truncated frame: wanted {n} bytes at offset {}", self.i);
        };
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        match self.take(1)? {
            [b] => Ok(*b),
            _ => bail!("truncated frame: wanted 1 byte at offset {}", self.i),
        }
    }

    fn u32(&mut self) -> Result<u32> {
        let mut a = [0u8; 4];
        a.copy_from_slice(self.take(4)?);
        Ok(u32::from_le_bytes(a))
    }

    fn u64(&mut self) -> Result<u64> {
        let mut a = [0u8; 8];
        a.copy_from_slice(self.take(8)?);
        Ok(u64::from_le_bytes(a))
    }

    fn f64(&mut self) -> Result<f64> {
        let mut a = [0u8; 8];
        a.copy_from_slice(self.take(8)?);
        Ok(f64::from_le_bytes(a))
    }

    fn i32(&mut self) -> Result<i32> {
        let mut a = [0u8; 4];
        a.copy_from_slice(self.take(4)?);
        Ok(i32::from_le_bytes(a))
    }

    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        if n > MAX_FRAME {
            bail!("bytes field of {n} exceeds frame bound");
        }
        Ok(self.take(n)?.to_vec())
    }

    fn opt_u64(&mut self) -> Result<Option<u64>> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.u64()?)),
            t => bail!("bad option tag {t}"),
        }
    }

    fn opt_i32s(&mut self) -> Result<Option<Vec<i32>>> {
        match self.u8()? {
            0 => Ok(None),
            1 => {
                let n = self.u32()? as usize;
                if n.saturating_mul(4) > MAX_FRAME {
                    bail!("i32 list of {n} exceeds frame bound");
                }
                let mut out = Vec::with_capacity(n.min(MAX_LIST));
                for _ in 0..n {
                    out.push(self.i32()?);
                }
                Ok(Some(out))
            }
            t => bail!("bad option tag {t}"),
        }
    }

    /// A reply payload at protocol version `v` (the span fields only
    /// exist on v5+ frames); mirror of `put_reply`.
    fn reply(&mut self, v: u8) -> Result<WireReply> {
        let mut r = WireReply {
            predicted: self.opt_u64()?,
            logits: self.opt_i32s()?,
            learned_way: self.opt_u64()?,
            sim_cycles: self.opt_u64()?,
            ..WireReply::default()
        };
        if v >= 5 {
            r.queue_us = self.opt_u64()?;
            r.service_us = self.opt_u64()?;
            r.write_us = self.opt_u64()?;
        }
        Ok(r)
    }

    fn finish(&self) -> Result<()> {
        if self.i != self.b.len() {
            bail!("{} trailing bytes after payload", self.b.len() - self.i);
        }
        Ok(())
    }
}

fn header(frame_body: &[u8]) -> Result<(u8, u8, u64, Cursor<'_>)> {
    let mut c = Cursor { b: frame_body, i: 0 };
    let version = c.u8()?;
    if !(MIN_VERSION..=VERSION).contains(&version) {
        bail!("unsupported protocol version {version} (accepting {MIN_VERSION}..={VERSION})");
    }
    let opcode = c.u8()?;
    let request_id = if version >= 3 { c.u64()? } else { 0 };
    Ok((version, opcode, request_id, c))
}

/// Best-effort pipelining tag of a frame body: the tag of a v3 frame whose
/// header is intact, else 0. Lets the server tag an error reply even when
/// the payload itself failed to decode.
pub fn peek_request_id(frame_body: &[u8]) -> u64 {
    let v3_plus = frame_body.first().is_some_and(|&v| v >= 3);
    match frame_body.get(2..10) {
        Some(tag) if v3_plus => {
            let mut a = [0u8; 8];
            a.copy_from_slice(tag);
            u64::from_le_bytes(a)
        }
        _ => 0,
    }
}

/// The stream opcodes only exist from protocol v2 on.
fn require_v2(version: u8, op: &str) -> Result<()> {
    if version < 2 {
        bail!("{op} requires protocol v2 (frame carries v{version})");
    }
    Ok(())
}

/// The batch opcodes only exist from protocol v3 on.
fn require_v3(version: u8, op: &str) -> Result<()> {
    if version < 3 {
        bail!("{op} requires protocol v3 (frame carries v{version})");
    }
    Ok(())
}

/// The continual-learning opcodes only exist from protocol v4 on.
fn require_v4(version: u8, op: &str) -> Result<()> {
    if version < 4 {
        bail!("{op} requires protocol v4 (frame carries v{version})");
    }
    Ok(())
}

/// The observability opcodes only exist from protocol v5 on.
fn require_v5(version: u8, op: &str) -> Result<()> {
    if version < 5 {
        bail!("{op} requires protocol v5 (frame carries v{version})");
    }
    Ok(())
}

/// The durability opcodes only exist from protocol v6 on.
fn require_v6(version: u8, op: &str) -> Result<()> {
    if version < 6 {
        bail!("{op} requires protocol v6 (frame carries v{version})");
    }
    Ok(())
}

/// Decode a request frame body (after the length prefix).
pub fn decode_request(frame_body: &[u8]) -> Result<RequestFrame> {
    let (version, opcode, request_id, mut c) = header(frame_body)?;
    let req = match opcode {
        OP_CLASSIFY => WireRequest::Classify { input: c.bytes()? },
        OP_CLASSIFY_SESSION => {
            WireRequest::ClassifySession { session: c.u64()?, input: c.bytes()? }
        }
        OP_LEARN_WAY => {
            let session = c.u64()?;
            let n = c.u32()? as usize;
            if n > MAX_LIST {
                bail!("learn frame with {n} shots");
            }
            let mut shots = Vec::with_capacity(n);
            for _ in 0..n {
                shots.push(c.bytes()?);
            }
            WireRequest::LearnWay { session, shots }
        }
        OP_EVICT_SESSION => WireRequest::EvictSession { session: c.u64()? },
        OP_HEALTH => WireRequest::Health,
        OP_METRICS => WireRequest::Metrics,
        OP_STREAM_OPEN => {
            require_v2(version, "StreamOpen")?;
            WireRequest::StreamOpen { session: c.u64()?, hop: c.u32()? }
        }
        OP_STREAM_PUSH => {
            require_v2(version, "StreamPush")?;
            WireRequest::StreamPush { session: c.u64()?, samples: c.bytes()? }
        }
        OP_STREAM_CLOSE => {
            require_v2(version, "StreamClose")?;
            WireRequest::StreamClose { session: c.u64()? }
        }
        OP_CLASSIFY_BATCH => {
            require_v3(version, "ClassifyBatch")?;
            let n = c.u32()? as usize;
            if n > MAX_LIST {
                bail!("batch frame with {n} windows");
            }
            let mut inputs = Vec::with_capacity(n);
            for _ in 0..n {
                inputs.push(c.bytes()?);
            }
            WireRequest::ClassifyBatch { inputs }
        }
        OP_ADD_SHOTS => {
            require_v4(version, "AddShots")?;
            let session = c.u64()?;
            let way = c.u64()?;
            // Same hostile-count bound as LearnWay: reject before the
            // count can drive allocation.
            let n = c.u32()? as usize;
            if n > MAX_LIST {
                bail!("add-shots frame with {n} shots");
            }
            let mut shots = Vec::with_capacity(n);
            for _ in 0..n {
                shots.push(c.bytes()?);
            }
            WireRequest::AddShots { session, way, shots }
        }
        OP_SESSION_INFO => {
            require_v4(version, "SessionInfo")?;
            WireRequest::SessionInfo { session: c.u64()? }
        }
        OP_STAT => {
            require_v5(version, "Stat")?;
            WireRequest::Stat
        }
        OP_SESSION_EXPORT => {
            require_v6(version, "SessionExport")?;
            WireRequest::SessionExport { session: c.u64()? }
        }
        OP_SESSION_IMPORT => {
            require_v6(version, "SessionImport")?;
            WireRequest::SessionImport { session: c.u64()?, blob: c.bytes()? }
        }
        op => bail!("unknown request opcode {op:#04x}"),
    };
    c.finish()?;
    Ok(RequestFrame { version, request_id, req })
}

/// Decode a response frame body (after the length prefix).
pub fn decode_response(frame_body: &[u8]) -> Result<ResponseFrame> {
    let (version, opcode, request_id, mut c) = header(frame_body)?;
    let resp = match opcode {
        OP_REPLY => WireResponse::Reply(c.reply(version)?),
        OP_HEALTH_REPLY => {
            let mut h = HealthWire {
                shards: c.u32()?,
                live_sessions: c.u64()?,
                input_len: c.u32()?,
                embed_dim: c.u32()?,
                window: 0,
                channels: 0,
            };
            if version >= 2 {
                h.window = c.u32()?;
                h.channels = c.u32()?;
            }
            WireResponse::Health(h)
        }
        OP_METRICS_REPLY => {
            let mut m = MetricsWire {
                requests: c.u64()?,
                completed: c.u64()?,
                errors: c.u64()?,
                rejected: c.u64()?,
                learn_ways: c.u64()?,
                evictions: c.u64()?,
                sim_cycles: c.u64()?,
                ..MetricsWire::default()
            };
            if version >= 2 {
                m.stream_chunks = c.u64()?;
                m.stream_decisions = c.u64()?;
            }
            if version >= 3 {
                m.worker_panics = c.u64()?;
            }
            if version >= 4 {
                m.add_shots = c.u64()?;
            }
            m.mean_latency_us = c.f64()?;
            m.p50_latency_us = c.f64()?;
            m.p95_latency_us = c.f64()?;
            m.p99_latency_us = c.f64()?;
            if version >= 5 {
                m.queue_depth = c.u64()?;
                m.in_flight = c.u64()?;
                m.sessions_live = c.u64()?;
                m.session_bytes = c.u64()?;
                m.backlog_hwm = c.u64()?;
                let n = c.u32()? as usize;
                // One row per op kind; even a future peer with more ops
                // stays far under this bound.
                if n > MAX_LIST {
                    bail!("per-op metrics list of {n} exceeds the {MAX_LIST}-row bound");
                }
                let mut per_op = Vec::with_capacity(n);
                for _ in 0..n {
                    per_op.push(OpMetricsWire {
                        op: c.u8()?,
                        count: c.u64()?,
                        p50_us: c.f64()?,
                        p95_us: c.f64()?,
                        p99_us: c.f64()?,
                    });
                }
                m.per_op = per_op;
            }
            WireResponse::Metrics(m)
        }
        OP_EVICTED => WireResponse::Evicted { existed: c.u8()? != 0 },
        OP_STREAM_OPENED => {
            require_v2(version, "StreamOpened")?;
            WireResponse::StreamOpened { window: c.u32()?, hop: c.u32()? }
        }
        OP_STREAM_DECISIONS => {
            require_v2(version, "StreamDecisions")?;
            let n = c.u32()? as usize;
            // Each decision is at least 28 bytes; bound before allocating
            // (capacity additionally capped — a hostile count must fail on
            // the truncated payload, not on a huge pre-allocation).
            if n.saturating_mul(28) > MAX_FRAME {
                bail!("decision list of {n} exceeds frame bound");
            }
            let mut ds = Vec::with_capacity(n.min(MAX_LIST));
            for _ in 0..n {
                let window = c.u64()?;
                let end_t = c.u64()?;
                let predicted = c.u64()?;
                let nl = c.u32()? as usize;
                if nl.saturating_mul(4) > MAX_FRAME {
                    bail!("logit list of {nl} exceeds frame bound");
                }
                let mut logits = Vec::with_capacity(nl.min(MAX_LIST));
                for _ in 0..nl {
                    logits.push(c.i32()?);
                }
                ds.push(WireDecision { window, end_t, predicted, logits });
            }
            WireResponse::StreamDecisions(ds)
        }
        OP_STREAM_CLOSED => {
            require_v2(version, "StreamClosed")?;
            WireResponse::StreamClosed { existed: c.u8()? != 0, windows: c.u64()? }
        }
        OP_REPLY_BATCH => {
            require_v3(version, "ReplyBatch")?;
            let n = c.u32()? as usize;
            // Requests cap their window count at MAX_LIST, so no honest
            // peer ever answers with more items — reject before the count
            // can drive allocation.
            if n > MAX_LIST {
                bail!("batch reply list of {n} exceeds the {MAX_LIST}-item bound");
            }
            let mut items = Vec::with_capacity(n);
            for _ in 0..n {
                items.push(match c.u8()? {
                    0 => BatchItem::Reply(c.reply(version)?),
                    1 => BatchItem::Error {
                        code: ErrorCode::from_u8(c.u8()?)?,
                        message: String::from_utf8_lossy(&c.bytes()?).into_owned(),
                    },
                    t => bail!("bad batch item tag {t}"),
                });
            }
            WireResponse::ReplyBatch(items)
        }
        OP_SESSION_INFO_REPLY => {
            require_v4(version, "SessionInfo")?;
            WireResponse::SessionInfo(SessionInfoWire {
                exists: c.u8()? != 0,
                ways: c.u64()?,
                shots: c.u64()?,
                bytes_used: c.u64()?,
                bytes_per_way: c.u32()?,
                way_cap: c.u64()?,
            })
        }
        OP_STAT_REPLY => {
            require_v5(version, "Stat")?;
            let recorded = c.u64()?;
            let overwritten = c.u64()?;
            let n = c.u32()? as usize;
            // Ring capacities are small; reject a hostile count before it
            // can drive allocation.
            if n > MAX_LIST {
                bail!("stat event list of {n} exceeds the {MAX_LIST}-item bound");
            }
            let mut events = Vec::with_capacity(n);
            for _ in 0..n {
                events.push(FlightEventWire {
                    seq: c.u64()?,
                    at_us: c.u64()?,
                    kind: c.u8()?,
                    op: c.u8()?,
                    detail: String::from_utf8_lossy(&c.bytes()?).into_owned(),
                });
            }
            let mut sessions = Vec::new();
            if version >= 6 {
                let ns = c.u32()? as usize;
                // Each id is 8 bytes; bound before allocating (capacity
                // additionally capped — a hostile count must fail on the
                // truncated payload, not on a huge pre-allocation).
                if ns.saturating_mul(8) > MAX_FRAME {
                    bail!("session id list of {ns} exceeds frame bound");
                }
                sessions = Vec::with_capacity(ns.min(MAX_LIST));
                for _ in 0..ns {
                    sessions.push(c.u64()?);
                }
            }
            WireResponse::Stat(StatWire { recorded, overwritten, events, sessions })
        }
        OP_SESSION_EXPORTED => {
            require_v6(version, "SessionExported")?;
            WireResponse::SessionExported { blob: c.bytes()? }
        }
        OP_ERROR => WireResponse::Error {
            code: ErrorCode::from_u8(c.u8()?)?,
            message: String::from_utf8_lossy(&c.bytes()?).into_owned(),
        },
        op => bail!("unknown response opcode {op:#04x}"),
    };
    c.finish()?;
    Ok(ResponseFrame { version, request_id, resp })
}

// ---------------------------------------------------------------------------
// Framed I/O
// ---------------------------------------------------------------------------

/// Consecutive read-timeout retries tolerated once a frame has started
/// arriving (at the server's 250 ms socket timeout this is ~10 s of
/// stall). A writer that starts a frame and then goes silent is dropped
/// instead of pinning its connection thread forever.
pub const MAX_STALL_RETRIES: u32 = 40;

/// Validate a decoded length prefix — shared by the blocking
/// [`read_frame`] path and the reactor's incremental [`frame_in`] framer,
/// so both reject hostile prefixes with identical wording.
pub fn check_frame_len(len: usize) -> Result<()> {
    if len < 2 {
        bail!("frame body of {len} bytes is too short for the header");
    }
    if len > MAX_FRAME {
        bail!("frame body of {len} bytes exceeds MAX_FRAME ({MAX_FRAME})");
    }
    Ok(())
}

/// Zero-copy incremental framing: if `buf` starts with one complete frame
/// (length prefix + body), return the body as a subslice of `buf` —
/// callers then consume `4 + body.len()` bytes. `Ok(None)` means the
/// frame is still arriving (fewer than 4 bytes, or a valid prefix whose
/// body is incomplete); `Err` means a hostile or corrupt length prefix,
/// after which the stream can no longer be trusted.
pub fn frame_in(buf: &[u8]) -> Result<Option<&[u8]>> {
    let Some(prefix) = buf.get(..4) else {
        return Ok(None);
    };
    let mut len_buf = [0u8; 4];
    len_buf.copy_from_slice(prefix);
    let len = u32::from_le_bytes(len_buf) as usize;
    check_frame_len(len)?;
    Ok(buf.get(4..4 + len))
}

/// Read one frame body. `Ok(None)` on clean EOF at a frame boundary;
/// `Err` on truncation mid-frame or a malformed length prefix.
///
/// On sockets with a read timeout, an *idle* connection (no bytes of the
/// next frame yet) surfaces the `WouldBlock`/`TimedOut` error so callers
/// can poll a shutdown flag; once the first byte of a frame has arrived,
/// timeouts are retried internally — up to [`MAX_STALL_RETRIES`] in a
/// row — so a slow writer cannot desynchronize the stream and a stalled
/// one cannot hold the thread hostage.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>> {
    let mut len_buf = [0u8; 4];
    let mut got = 0;
    let mut stalls = 0u32;
    while got < 4 {
        let Some(dst) = len_buf.get_mut(got..) else {
            bail!("frame length cursor out of range");
        };
        match r.read(dst) {
            Ok(0) => {
                if got == 0 {
                    return Ok(None); // clean EOF between frames
                }
                bail!("EOF inside frame length prefix");
            }
            Ok(n) => {
                got += n;
                stalls = 0;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e)
                if got > 0
                    && matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) =>
            {
                stalls += 1;
                if stalls > MAX_STALL_RETRIES {
                    bail!("peer stalled inside frame length prefix");
                }
                continue; // mid-frame: keep waiting for the writer
            }
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    check_frame_len(len)?;
    let mut buf = vec![0u8; len];
    let mut got = 0;
    let mut stalls = 0u32;
    while got < len {
        let Some(dst) = buf.get_mut(got..) else {
            bail!("frame body cursor out of range at {got}/{len} bytes");
        };
        match r.read(dst) {
            Ok(0) => bail!("EOF inside frame body at {got}/{len} bytes"),
            Ok(n) => {
                got += n;
                stalls = 0;
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                stalls += 1;
                if stalls > MAX_STALL_RETRIES {
                    bail!("peer stalled inside frame body at {got}/{len} bytes");
                }
                continue;
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(Some(buf))
}

/// Write one already-encoded frame (length prefix included).
pub fn write_frame<W: Write>(w: &mut W, frame: &[u8]) -> Result<()> {
    w.write_all(frame)?;
    w.flush()?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_in_matches_read_frame_semantics() {
        let frame = encode_request_versioned(&WireRequest::Health, VERSION, 7);
        // Whole frame available: the body subslice is what read_frame
        // would have produced from the same bytes.
        let body = frame_in(&frame).unwrap().expect("complete frame");
        let via_reader = read_frame(&mut &frame[..]).unwrap().expect("complete frame");
        assert_eq!(body, &via_reader[..]);
        assert_eq!(4 + body.len(), frame.len());
        // Every strict prefix is "still arriving".
        for cut in 0..frame.len() {
            assert!(frame_in(&frame[..cut]).unwrap().is_none(), "cut at {cut}");
        }
        // Trailing bytes of the next frame are left alone.
        let mut two = frame.clone();
        two.extend_from_slice(&frame);
        assert_eq!(frame_in(&two).unwrap().expect("first frame"), body);
        // Hostile prefixes fail exactly like the blocking reader.
        let hostile = [(1u32, "too short"), (u32::MAX, "exceeds MAX_FRAME")];
        for (len, needle) in hostile {
            let mut bad = len.to_le_bytes().to_vec();
            bad.extend_from_slice(&[0u8; 8]);
            let e = frame_in(&bad).unwrap_err().to_string();
            assert!(e.contains(needle), "{e}");
            let r = read_frame(&mut &bad[..]).unwrap_err().to_string();
            assert_eq!(e, r, "frame_in and read_frame must agree on {len}");
        }
    }

    /// Every request opcode (v1 classify/learn ops through the v5 stat
    /// dump), each with an empty/minimal and a maximal-field variant —
    /// the corpus the table-driven tests below drive through round-trip,
    /// truncation and hostile-count checks.
    fn request_corpus() -> Vec<WireRequest> {
        vec![
            WireRequest::Classify { input: vec![] },
            WireRequest::Classify { input: (0..64).map(|i| i % 16).collect() },
            WireRequest::ClassifySession { session: 0, input: vec![15; 3] },
            WireRequest::ClassifySession { session: u64::MAX, input: vec![] },
            WireRequest::LearnWay { session: 7, shots: vec![] },
            WireRequest::LearnWay {
                session: 42,
                shots: vec![vec![1, 2, 3], vec![], vec![15; 100]],
            },
            WireRequest::EvictSession { session: 1 << 63 },
            WireRequest::Health,
            WireRequest::Metrics,
            WireRequest::StreamOpen { session: 3, hop: 1 },
            WireRequest::StreamOpen { session: u64::MAX, hop: u32::MAX },
            WireRequest::StreamPush { session: 9, samples: vec![] },
            WireRequest::StreamPush { session: 9, samples: (0..200).map(|i| i % 16).collect() },
            WireRequest::StreamClose { session: 0 },
            WireRequest::ClassifyBatch { inputs: vec![] },
            WireRequest::ClassifyBatch { inputs: vec![vec![1, 2, 3], vec![], vec![15; 64]] },
            WireRequest::AddShots { session: 7, way: 0, shots: vec![] },
            WireRequest::AddShots {
                session: u64::MAX,
                way: 249,
                shots: vec![vec![1, 2, 3], vec![], vec![15; 100]],
            },
            WireRequest::SessionInfo { session: 0 },
            WireRequest::SessionInfo { session: u64::MAX },
            WireRequest::Stat,
            WireRequest::SessionExport { session: 0 },
            WireRequest::SessionExport { session: u64::MAX },
            WireRequest::SessionImport { session: 7, blob: vec![] },
            WireRequest::SessionImport {
                session: u64::MAX,
                blob: (0..255u8).collect(),
            },
        ]
    }

    /// Every response opcode, same coverage discipline as
    /// [`request_corpus`].
    fn response_corpus() -> Vec<WireResponse> {
        let mut out = vec![
            WireResponse::Reply(WireReply::default()),
            WireResponse::Reply(WireReply {
                predicted: Some(3),
                logits: Some(vec![i32::MIN, -1, 0, 1, i32::MAX]),
                learned_way: Some(0),
                sim_cycles: Some(u64::MAX),
                queue_us: Some(12),
                service_us: Some(3400),
                write_us: Some(0),
            }),
            WireResponse::Health(HealthWire {
                shards: 4,
                live_sessions: 123,
                input_len: 64,
                embed_dim: 8,
                window: 16,
                channels: 4,
            }),
            WireResponse::Metrics(MetricsWire {
                requests: 1,
                completed: 2,
                errors: 3,
                rejected: 4,
                learn_ways: 5,
                evictions: 6,
                sim_cycles: 7,
                stream_chunks: 8,
                stream_decisions: 9,
                worker_panics: 10,
                add_shots: 11,
                mean_latency_us: 1.5,
                p50_latency_us: 2.5,
                p95_latency_us: 100.0,
                p99_latency_us: 1e6,
                queue_depth: 12,
                in_flight: 13,
                sessions_live: 14,
                session_bytes: 15,
                backlog_hwm: 16,
                per_op: vec![
                    OpMetricsWire { op: 0, count: 17, p50_us: 1.0, p95_us: 2.0, p99_us: 3.0 },
                    OpMetricsWire { op: 10, count: 0, p50_us: 0.0, p95_us: 0.0, p99_us: 0.0 },
                ],
            }),
            WireResponse::Evicted { existed: true },
            WireResponse::Evicted { existed: false },
            WireResponse::StreamOpened { window: 16, hop: 4 },
            WireResponse::StreamDecisions(vec![]),
            WireResponse::StreamDecisions(vec![
                WireDecision { window: 0, end_t: 15, predicted: 3, logits: vec![1, -2, 3] },
                WireDecision {
                    window: u64::MAX,
                    end_t: u64::MAX,
                    predicted: 0,
                    logits: vec![i32::MIN, i32::MAX],
                },
                WireDecision { window: 2, end_t: 23, predicted: 1, logits: vec![] },
            ]),
            WireResponse::StreamClosed { existed: true, windows: 42 },
            WireResponse::StreamClosed { existed: false, windows: 0 },
            WireResponse::ReplyBatch(vec![]),
            WireResponse::ReplyBatch(vec![
                BatchItem::Reply(WireReply {
                    predicted: Some(1),
                    logits: Some(vec![-5, 9]),
                    learned_way: None,
                    sim_cycles: None,
                    queue_us: Some(1),
                    service_us: Some(2),
                    write_us: None,
                }),
                BatchItem::Error { code: ErrorCode::Overloaded, message: "shard full".into() },
                BatchItem::Reply(WireReply::default()),
                BatchItem::Error { code: ErrorCode::App, message: String::new() },
            ]),
            WireResponse::SessionInfo(SessionInfoWire::default()),
            WireResponse::SessionInfo(SessionInfoWire {
                exists: true,
                ways: 250,
                shots: 2500,
                bytes_used: 250 * 26,
                bytes_per_way: 26,
                way_cap: u64::MAX,
            }),
            WireResponse::Error { code: ErrorCode::App, message: String::new() },
            WireResponse::Stat(StatWire::default()),
            WireResponse::Stat(StatWire {
                recorded: 300,
                overwritten: 44,
                events: vec![
                    FlightEventWire {
                        seq: 256,
                        at_us: 1_000_000,
                        kind: 1,
                        op: 2,
                        detail: "chaos engine: injected panic".into(),
                    },
                    FlightEventWire {
                        seq: 257,
                        at_us: 1_000_400,
                        kind: 9,
                        op: 99,
                        detail: "".into(),
                    },
                ],
                sessions: vec![0, 7, u64::MAX],
            }),
            WireResponse::SessionExported { blob: vec![] },
            WireResponse::SessionExported { blob: (0..255u8).rev().collect() },
        ];
        for code in [ErrorCode::Overloaded, ErrorCode::Malformed, ErrorCode::App] {
            out.push(WireResponse::Error { code, message: "queue full".into() });
        }
        out
    }

    /// Every corpus message at every protocol version v1..=v5: the frame
    /// reads back through `read_frame`, decodes, echoes the pipelining
    /// tag exactly when the effective version carries one, round-trips
    /// with full fidelity at [`VERSION`], and — at *every* version —
    /// re-encoding the decoded frame reproduces the identical bytes, so
    /// each (message, version) pair has one canonical representation.
    #[test]
    fn corpus_roundtrips_at_every_version() {
        const TAG: u64 = 0xDEAD_BEEF;
        for req in request_corpus() {
            for v in MIN_VERSION..=VERSION {
                let frame = encode_request_versioned(&req, v, TAG);
                let mut r = std::io::Cursor::new(frame.clone());
                let blob = read_frame(&mut r).unwrap().unwrap();
                assert_eq!(blob.len() + 4, frame.len());
                let got = decode_request(&blob).unwrap();
                assert_eq!(got.version, v.max(request_min_version(&req)), "{req:?} at v{v}");
                let want_tag = if got.version >= 3 { TAG } else { 0 };
                assert_eq!(got.request_id, want_tag, "{req:?} at v{v}");
                // Request payloads are version-independent (only gated),
                // so decode is full-fidelity at every version.
                assert_eq!(got.req, req, "{req:?} at v{v}");
                let again = encode_request_versioned(&got.req, got.version, got.request_id);
                assert_eq!(again, frame, "{req:?} at v{v} must re-encode canonically");
            }
        }
        for resp in response_corpus() {
            for v in MIN_VERSION..=VERSION {
                let frame = encode_response_versioned(&resp, v, TAG);
                let got = decode_response(&frame[4..]).unwrap();
                let want_tag = if got.version >= 3 { TAG } else { 0 };
                assert_eq!(got.request_id, want_tag, "{resp:?} at v{v}");
                if got.version == VERSION {
                    assert_eq!(got.resp, resp, "full fidelity at v{VERSION}");
                }
                // Older versions drop newer payload fields; the canonical
                // byte check still pins their exact shape.
                let again = encode_response_versioned(&got.resp, got.version, got.request_id);
                assert_eq!(again, frame, "{resp:?} at v{v} must re-encode canonically");
            }
        }
    }

    #[test]
    fn responses_downgrade_for_older_peers() {
        // A v1 peer must receive a strictly v1-shaped frame: version byte
        // 1, no tag, and no v2/v3-appended payload fields.
        let h = HealthWire {
            shards: 2,
            live_sessions: 5,
            input_len: 64,
            embed_dim: 8,
            window: 16,
            channels: 4,
        };
        let frame = encode_response_versioned(&WireResponse::Health(h.clone()), 1, 99);
        let body = &frame[4..];
        assert_eq!(body[0], 1, "version byte must be the peer's");
        // Strict decode (as this crate's v1 shipped): exactly 2 + 4 + 8 +
        // 4 + 4 bytes — no tag, no trailing window/channels.
        assert_eq!(body.len(), 2 + 4 + 8 + 4 + 4);
        match decode_response(body).unwrap().resp {
            WireResponse::Health(got) => {
                assert_eq!(got.shards, h.shards);
                assert_eq!(got.window, 0, "v2 fields dropped at v1");
                assert_eq!(got.channels, 0);
            }
            other => panic!("expected Health, got {other:?}"),
        }
        // Metrics at v3 keep the panic counter but lose the v4 add_shots.
        let m = MetricsWire {
            stream_chunks: 7,
            stream_decisions: 9,
            worker_panics: 3,
            add_shots: 4,
            ..MetricsWire::default()
        };
        let frame = encode_response_versioned(&WireResponse::Metrics(m.clone()), 3, 0);
        assert_eq!(frame[4], 3);
        match decode_response(&frame[4..]).unwrap().resp {
            WireResponse::Metrics(got) => {
                assert_eq!(got.worker_panics, 3);
                assert_eq!(got.add_shots, 0, "v4 field dropped at v3");
            }
            other => panic!("expected Metrics, got {other:?}"),
        }
        // ... at v2 also lose worker_panics ...
        let frame = encode_response_versioned(&WireResponse::Metrics(m.clone()), 2, 0);
        assert_eq!(frame[4], 2);
        match decode_response(&frame[4..]).unwrap().resp {
            WireResponse::Metrics(got) => {
                assert_eq!(got.stream_chunks, 7);
                assert_eq!(got.stream_decisions, 9);
                assert_eq!(got.worker_panics, 0, "v3 field dropped at v2");
                assert_eq!(got.add_shots, 0);
            }
            other => panic!("expected Metrics, got {other:?}"),
        }
        // ... and at v1 also lose the stream counters.
        let frame = encode_response_versioned(&WireResponse::Metrics(m), 1, 0);
        match decode_response(&frame[4..]).unwrap().resp {
            WireResponse::Metrics(got) => {
                assert_eq!(got.stream_chunks, 0);
                assert_eq!(got.stream_decisions, 0);
                assert_eq!(got.worker_panics, 0);
                assert_eq!(got.add_shots, 0);
            }
            other => panic!("expected Metrics, got {other:?}"),
        }
        // A v4 peer's Reply keeps the base fields but loses the v5 span
        // decomposition.
        let r = WireReply {
            predicted: Some(7),
            queue_us: Some(10),
            service_us: Some(20),
            write_us: Some(30),
            ..WireReply::default()
        };
        let frame = encode_response_versioned(&WireResponse::Reply(r), 4, 0);
        assert_eq!(frame[4], 4);
        match decode_response(&frame[4..]).unwrap().resp {
            WireResponse::Reply(got) => {
                assert_eq!(got.predicted, Some(7));
                assert_eq!(got.queue_us, None, "v5 span fields dropped at v4");
                assert_eq!(got.service_us, None);
                assert_eq!(got.write_us, None);
            }
            other => panic!("expected Reply, got {other:?}"),
        }
        // A v4 peer's Metrics loses the v5 gauges and per-op table.
        let m = MetricsWire {
            add_shots: 4,
            queue_depth: 5,
            in_flight: 6,
            sessions_live: 7,
            session_bytes: 8,
            backlog_hwm: 9,
            per_op: vec![OpMetricsWire { op: 0, count: 3, ..OpMetricsWire::default() }],
            ..MetricsWire::default()
        };
        let frame = encode_response_versioned(&WireResponse::Metrics(m), 4, 0);
        assert_eq!(frame[4], 4);
        match decode_response(&frame[4..]).unwrap().resp {
            WireResponse::Metrics(got) => {
                assert_eq!(got.add_shots, 4, "v4 field survives at v4");
                assert_eq!(got.queue_depth, 0, "v5 gauges dropped at v4");
                assert_eq!(got.backlog_hwm, 0);
                assert!(got.per_op.is_empty(), "v5 per-op table dropped at v4");
            }
            other => panic!("expected Metrics, got {other:?}"),
        }
        // A v5 peer's Stat keeps the events but loses the v6 session ids.
        let st = StatWire {
            recorded: 3,
            overwritten: 0,
            events: vec![],
            sessions: vec![7, 9],
        };
        let frame = encode_response_versioned(&WireResponse::Stat(st), 5, 0);
        assert_eq!(frame[4], 5);
        match decode_response(&frame[4..]).unwrap().resp {
            WireResponse::Stat(got) => {
                assert_eq!(got.recorded, 3);
                assert!(got.sessions.is_empty(), "v6 session ids dropped at v5");
            }
            other => panic!("expected Stat, got {other:?}"),
        }
        // Stream responses cannot drop below v2; batch not below v3;
        // continual-learning info not below v4; the stat dump not below v5;
        // the session-snapshot blob not below v6.
        let frame = encode_response_versioned(&WireResponse::Stat(StatWire::default()), 1, 0);
        assert_eq!(frame[4], 5);
        let frame = encode_request_versioned(&WireRequest::Stat, 1, 0);
        assert_eq!(frame[4], 5, "a Stat request cannot be down-versioned");
        let frame =
            encode_response_versioned(&WireResponse::StreamOpened { window: 16, hop: 4 }, 1, 0);
        assert_eq!(frame[4], 2);
        let frame = encode_response_versioned(&WireResponse::ReplyBatch(vec![]), 1, 0);
        assert_eq!(frame[4], 3);
        let frame = encode_response_versioned(
            &WireResponse::SessionInfo(SessionInfoWire::default()),
            1,
            0,
        );
        assert_eq!(frame[4], 4);
        let frame =
            encode_response_versioned(&WireResponse::SessionExported { blob: vec![1] }, 1, 0);
        assert_eq!(frame[4], 6);
        let frame = encode_request_versioned(&WireRequest::SessionExport { session: 1 }, 1, 0);
        assert_eq!(frame[4], 6, "a SessionExport request cannot be down-versioned");
        // Out-of-range versions clamp instead of producing junk frames.
        let frame = encode_response_versioned(&WireResponse::Evicted { existed: true }, 9, 0);
        assert_eq!(frame[4], VERSION);
    }

    #[test]
    fn pre_v3_frames_decode_untagged() {
        // v1 and v2 frames carry no request id; it reads back as 0 and the
        // version is preserved for the reply path.
        for v in [1u8, 2] {
            let frame = encode_request_versioned(&WireRequest::Health, v, 0xFFFF);
            let got = decode_request(&frame[4..]).unwrap();
            assert_eq!(got.version, v);
            assert_eq!(got.request_id, 0, "pre-v3 frames cannot carry a tag");
            assert_eq!(got.req, WireRequest::Health);
            // Header is exactly version + opcode: 2 bytes.
            assert_eq!(frame.len(), 4 + 2);
        }
        // A v3 Health frame is 8 bytes longer (the tag).
        let frame = encode_request_versioned(&WireRequest::Health, 3, 0xFFFF);
        assert_eq!(frame.len(), 4 + 10);
    }

    #[test]
    fn peek_request_id_is_best_effort() {
        let frame = encode_request_versioned(&WireRequest::Health, 3, 12345);
        assert_eq!(peek_request_id(&frame[4..]), 12345);
        let frame = encode_request_versioned(&WireRequest::Health, 2, 12345);
        assert_eq!(peek_request_id(&frame[4..]), 0, "v2 frames have no tag");
        assert_eq!(peek_request_id(&[3u8, OP_HEALTH]), 0, "truncated header");
        assert_eq!(peek_request_id(&[]), 0);
    }

    #[test]
    fn version_gated_ops_are_rejected_in_old_frames() {
        // A v1 Health request decodes fine.
        assert_eq!(decode_request(&[1, OP_HEALTH]).unwrap().req, WireRequest::Health);
        // A v1 Health *reply* decodes with the v2 geometry fields zeroed.
        let mut body = vec![1u8, OP_HEALTH_REPLY];
        put_u32(&mut body, 2); // shards
        put_u64(&mut body, 5); // live_sessions
        put_u32(&mut body, 64); // input_len
        put_u32(&mut body, 8); // embed_dim
        match decode_response(&body).unwrap().resp {
            WireResponse::Health(h) => {
                assert_eq!(h.shards, 2);
                assert_eq!(h.window, 0, "v1 reply lacks stream geometry");
                assert_eq!(h.channels, 0);
            }
            other => panic!("expected Health, got {other:?}"),
        }
        // Stream ops inside a v1 frame are malformed.
        let mut body = vec![1u8, OP_STREAM_CLOSE];
        put_u64(&mut body, 7);
        assert!(decode_request(&body).is_err(), "v1 frame must not carry stream ops");
        let mut body = vec![1u8, OP_STREAM_OPEN];
        put_u64(&mut body, 7);
        put_u32(&mut body, 1);
        assert!(decode_request(&body).is_err());
        // Batch ops inside a v2 frame are malformed.
        let mut body = vec![2u8, OP_CLASSIFY_BATCH];
        put_u32(&mut body, 0);
        assert!(decode_request(&body).is_err(), "v2 frame must not carry batch ops");
        let mut body = vec![2u8, OP_REPLY_BATCH];
        put_u32(&mut body, 0);
        assert!(decode_response(&body).is_err());
        // Continual-learning ops inside a v3 frame are malformed (and a
        // fortiori inside v1/v2 frames, which also lack the tag).
        let mut body = head(3, OP_ADD_SHOTS, 0);
        put_u64(&mut body, 1);
        put_u64(&mut body, 0);
        put_u32(&mut body, 0);
        let err = decode_request(&body).unwrap_err();
        assert!(format!("{err:#}").contains("v4"), "{err:#}");
        let mut body = head(3, OP_SESSION_INFO, 0);
        put_u64(&mut body, 1);
        assert!(decode_request(&body).is_err(), "v3 frame must not carry SessionInfo");
        let mut body = vec![2u8, OP_SESSION_INFO];
        put_u64(&mut body, 1);
        assert!(decode_request(&body).is_err());
        let mut body = head(3, OP_SESSION_INFO_REPLY, 0);
        body.push(0);
        for _ in 0..3 {
            put_u64(&mut body, 0);
        }
        put_u32(&mut body, 0);
        put_u64(&mut body, 0);
        assert!(decode_response(&body).is_err(), "v3 frame must not carry a SessionInfo reply");
        // Stat ops inside a v4 frame are malformed (and a fortiori inside
        // older frames).
        let body = head(4, OP_STAT, 0);
        let err = decode_request(&body).unwrap_err();
        assert!(format!("{err:#}").contains("v5"), "{err:#}");
        let body = vec![2u8, OP_STAT];
        assert!(decode_request(&body).is_err());
        let mut body = head(4, OP_STAT_REPLY, 0);
        put_u64(&mut body, 0);
        put_u64(&mut body, 0);
        put_u32(&mut body, 0);
        let err = decode_response(&body).unwrap_err();
        assert!(format!("{err:#}").contains("v5"), "{err:#}");
        // Durability ops inside a v5 frame are malformed (and a fortiori
        // inside older frames).
        let mut body = head(5, OP_SESSION_EXPORT, 0);
        put_u64(&mut body, 1);
        let err = decode_request(&body).unwrap_err();
        assert!(format!("{err:#}").contains("v6"), "{err:#}");
        let mut body = head(5, OP_SESSION_IMPORT, 0);
        put_u64(&mut body, 1);
        put_u32(&mut body, 0);
        let err = decode_request(&body).unwrap_err();
        assert!(format!("{err:#}").contains("v6"), "{err:#}");
        let mut body = vec![2u8, OP_SESSION_IMPORT];
        put_u64(&mut body, 1);
        put_u32(&mut body, 0);
        assert!(decode_request(&body).is_err());
        let mut body = head(5, OP_SESSION_EXPORTED, 0);
        put_u32(&mut body, 0);
        let err = decode_response(&body).unwrap_err();
        assert!(format!("{err:#}").contains("v6"), "{err:#}");
    }

    /// Every corpus frame at every version, truncated at *every* byte
    /// boundary, is malformed: decode returns an error — it never panics
    /// and never decodes "by luck" into a shorter message. A trailing
    /// byte after a well-formed payload is malformed too (strict decode),
    /// as are an out-of-range version byte and an unknown opcode.
    #[test]
    fn corpus_rejects_truncation_trailing_bytes_and_bad_headers() {
        let mut blobs: Vec<(String, Vec<u8>, bool)> = Vec::new();
        for req in request_corpus() {
            for v in MIN_VERSION..=VERSION {
                let frame = encode_request_versioned(&req, v, 1);
                blobs.push((format!("{req:?} v{v}"), frame[4..].to_vec(), true));
            }
        }
        for resp in response_corpus() {
            for v in MIN_VERSION..=VERSION {
                let frame = encode_response_versioned(&resp, v, 1);
                blobs.push((format!("{resp:?} v{v}"), frame[4..].to_vec(), false));
            }
        }
        for (what, blob, is_req) in &blobs {
            let fails = |b: &[u8]| {
                if *is_req {
                    decode_request(b).is_err()
                } else {
                    decode_response(b).is_err()
                }
            };
            for cut in 0..blob.len() {
                assert!(fails(&blob[..cut]), "{what}: cut at {cut} must fail");
            }
            let mut long = blob.clone();
            long.push(0);
            assert!(fails(&long), "{what}: trailing byte must fail");
            let mut bad = blob.clone();
            bad[0] = VERSION + 1;
            assert!(fails(&bad), "{what}: future version byte must fail");
            bad[0] = 0;
            assert!(fails(&bad), "{what}: version 0 must fail");
        }
        assert!(decode_request(&[1, 0x77]).is_err(), "unknown request opcode");
        assert!(decode_response(&[1, 0x00]).is_err(), "unknown response opcode");
    }

    #[test]
    fn read_frame_rejects_hostile_lengths() {
        // over-large length prefix
        let mut r = std::io::Cursor::new((u32::MAX).to_le_bytes().to_vec());
        assert!(read_frame(&mut r).is_err());
        // too-short body length
        let mut r = std::io::Cursor::new(1u32.to_le_bytes().to_vec());
        assert!(read_frame(&mut r).is_err());
        // truncated mid-frame
        let mut partial = 10u32.to_le_bytes().to_vec();
        partial.extend_from_slice(&[1, OP_HEALTH]);
        let mut r = std::io::Cursor::new(partial);
        assert!(read_frame(&mut r).is_err());
        // clean EOF
        let mut r = std::io::Cursor::new(Vec::<u8>::new());
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    #[test]
    fn frames_concatenate_on_a_stream() {
        let mut stream = Vec::new();
        stream.extend_from_slice(&encode_request(&WireRequest::Health));
        stream.extend_from_slice(&encode_request(&WireRequest::EvictSession { session: 2 }));
        let mut r = std::io::Cursor::new(stream);
        let a = decode_request(&read_frame(&mut r).unwrap().unwrap()).unwrap();
        let b = decode_request(&read_frame(&mut r).unwrap().unwrap()).unwrap();
        assert_eq!(a.req, WireRequest::Health);
        assert_eq!(b.req, WireRequest::EvictSession { session: 2 });
        assert!(read_frame(&mut r).unwrap().is_none());
    }

    /// Every list- or bytes-bearing field on the wire, fed a hostile
    /// count (first value past the bound, and u32::MAX): decode must be
    /// malformed *before* the count can drive allocation — the decoder
    /// bounds each count against [`MAX_LIST`] / [`MAX_FRAME`] or caps
    /// pre-allocation and fails on the truncated payload.
    #[test]
    fn corpus_hostile_counts_are_rejected_before_allocation() {
        for n in [(MAX_LIST + 1) as u32, u32::MAX] {
            // LearnWay shot count.
            let mut body = head(VERSION, OP_LEARN_WAY, 0);
            put_u64(&mut body, 1);
            put_u32(&mut body, n);
            assert!(decode_request(&body).is_err(), "LearnWay x{n}");
            // ClassifyBatch window count.
            let mut body = head(VERSION, OP_CLASSIFY_BATCH, 0);
            put_u32(&mut body, n);
            assert!(decode_request(&body).is_err(), "ClassifyBatch x{n}");
            // AddShots shot count (shares LearnWay's bound).
            let mut body = head(VERSION, OP_ADD_SHOTS, 0);
            put_u64(&mut body, 1);
            put_u64(&mut body, 0);
            put_u32(&mut body, n);
            let err = decode_request(&body).unwrap_err();
            assert!(format!("{err:#}").contains("shots"), "{err:#}");
            // ReplyBatch item count.
            let mut body = head(VERSION, OP_REPLY_BATCH, 0);
            put_u32(&mut body, n);
            assert!(decode_response(&body).is_err(), "ReplyBatch x{n}");
            // Stat flight-event count.
            let mut body = head(VERSION, OP_STAT_REPLY, 0);
            put_u64(&mut body, 0);
            put_u64(&mut body, 0);
            put_u32(&mut body, n);
            let err = decode_response(&body).unwrap_err();
            assert!(format!("{err:#}").contains("stat event list"), "{err:#}");
            // v6 Stat session-id count (bounded against the frame cap;
            // smaller hostile counts fail on the truncated payload).
            let mut body = head(VERSION, OP_STAT_REPLY, 0);
            put_u64(&mut body, 0);
            put_u64(&mut body, 0);
            put_u32(&mut body, 0); // no events
            put_u32(&mut body, n);
            assert!(decode_response(&body).is_err(), "Stat session ids x{n}");
            // v5 Metrics per-op row count.
            let mut body = head(VERSION, OP_METRICS_REPLY, 0);
            for _ in 0..11 {
                put_u64(&mut body, 0); // counters through add_shots
            }
            for _ in 0..4 {
                put_f64(&mut body, 0.0); // latency percentiles
            }
            for _ in 0..5 {
                put_u64(&mut body, 0); // v5 gauges
            }
            put_u32(&mut body, n);
            let err = decode_response(&body).unwrap_err();
            assert!(format!("{err:#}").contains("per-op"), "{err:#}");
        }
        // Bytes fields claiming up to 4 GiB are bounded by the frame cap.
        let mut body = head(VERSION, OP_CLASSIFY, 0);
        put_u32(&mut body, u32::MAX);
        assert!(decode_request(&body).is_err(), "Classify input claiming 4 GiB");
        let mut body = head(VERSION, OP_ADD_SHOTS, 0);
        put_u64(&mut body, 1);
        put_u64(&mut body, 0);
        put_u32(&mut body, 1);
        put_u32(&mut body, u32::MAX); // the one shot claims 4 GiB
        assert!(decode_request(&body).is_err(), "AddShots shot claiming 4 GiB");
        let mut body = head(VERSION, OP_ERROR, 0);
        body.push(3);
        put_u32(&mut body, u32::MAX);
        assert!(decode_response(&body).is_err(), "Error message claiming 4 GiB");
        let mut body = head(VERSION, OP_SESSION_IMPORT, 0);
        put_u64(&mut body, 1);
        put_u32(&mut body, u32::MAX);
        assert!(decode_request(&body).is_err(), "SessionImport blob claiming 4 GiB");
        let mut body = head(VERSION, OP_SESSION_EXPORTED, 0);
        put_u32(&mut body, u32::MAX);
        assert!(decode_response(&body).is_err(), "SessionExported blob claiming 4 GiB");
        // Counts whose decode caps pre-allocation instead of rejecting
        // outright (logits, stream decisions) still fail on the truncated
        // payload without ever allocating the claimed size.
        let mut body = head(VERSION, OP_REPLY, 0);
        body.push(0); // predicted: None
        body.push(1); // logits: Some, claiming ~500M entries
        put_u32(&mut body, u32::MAX / 8);
        assert!(decode_response(&body).is_err(), "hostile logit count");
        let mut body = head(VERSION, OP_STREAM_DECISIONS, 0);
        put_u32(&mut body, u32::MAX);
        assert!(decode_response(&body).is_err(), "hostile decision count");
    }
}
