//! Open-loop load generators for the serve layer: Poisson request traffic
//! ([`run`]) and paced streaming-session traffic ([`run_stream`]).
//!
//! Arrival times are pre-drawn (requests) or fixed by the pacing rate
//! (stream chunks) and *do not* adapt to response latency (open-loop): if
//! the server falls behind, arrivals queue on the worker threads and the
//! measured latency — taken from each request's **scheduled** arrival
//! time, not its actual send time — faithfully includes that coordination
//! delay. This avoids the closed-loop trap where a slow server throttles
//! its own load and the tail disappears from the histogram.
//!
//! Request-mode traffic mix: each arrival is a `LearnWay` with probability
//! `learn_frac` (k random shots on a random session), otherwise a
//! `ClassifySession` on a random pre-warmed session. Sessions span all
//! shards, so a run exercises cross-shard routing by construction.
//!
//! Two protocol-v3 load shapes stack on top:
//!
//! * `pipeline: D` keeps up to D requests in flight per connection via
//!   [`Client::submit`]/[`Client::wait`] instead of one blocking call at a
//!   time — a single connection can then saturate every shard;
//! * `batch: N` replaces the session mix with `ClassifyBatch` frames of N
//!   session-less windows each (requires a model with a built-in head).
//!
//! Stream mode opens one stream session per connection and pushes
//! fixed-size chunks, paced to a sample rate (e.g. 16 kHz audio) or
//! free-running; it reports **per-chunk** and **per-decision** latency
//! separately, since a decision's latency is what an end user of
//! streaming KWS actually observes.
//!
//! CL mode ([`run_cl`]) drives continual learning as a workload: each
//! connection owns one growing-way session and mixes `LearnWay` (new
//! ways), `AddShots` (running-mean updates to existing ways, protocol
//! v4) and `ClassifySession` ops until the session reaches its
//! ways x shots target, then evicts it and grows again — per-op latency
//! percentiles are reported separately for learns, updates and
//! classifies.
//!
//! Fan-out mode ([`run_fanout`]) is the fleet shape instead of the
//! throughput shape: hold very many connections open simultaneously
//! (thousands — the reactor backend's reason to exist), pipeline a few
//! requests on every one of them at once, and measure the turnaround.
//! One driver thread multiplexes all connections, so the measurement
//! stays honest on small hosts.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::metrics::{HistSnapshot, LatencyHistogram};
use crate::serve::client::{Client, ClientConfig, Outcome};
use crate::serve::proto::{BatchItem, ErrorCode, MetricsWire, WireRequest, WireResponse};
use crate::util::rng::Rng;

/// Load generator configuration.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    pub addr: String,
    /// Offered load in requests per second (Poisson arrivals).
    pub rps: f64,
    pub duration: Duration,
    /// Fraction of arrivals that are `LearnWay` ops (rest classify).
    pub learn_frac: f64,
    /// Session-id space (1..=sessions), warmed before the run starts.
    pub sessions: u64,
    /// Shots per learn op.
    pub shots: usize,
    /// Worker connections draining the arrival schedule.
    pub connections: usize,
    /// Requests kept in flight per connection (protocol-v3 pipelining);
    /// 1 = the classic one-blocking-call-at-a-time client.
    pub pipeline: usize,
    /// When > 0, every arrival is a `ClassifyBatch` of this many
    /// session-less windows instead of the session mix (needs a model
    /// with a built-in head).
    pub batch: usize,
    /// When > 0, print an in-flight progress line to stderr every this
    /// many seconds: completed throughput plus p50/p95/p99 over the
    /// *interval* (a [`HistSnapshot::delta`] against the previous tick),
    /// so a tail that develops mid-run is visible before the final report
    /// averages it away.
    pub report_secs: u64,
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:7070".to_string(),
            rps: 200.0,
            duration: Duration::from_secs(10),
            learn_frac: 0.05,
            sessions: 16,
            shots: 2,
            connections: 4,
            pipeline: 1,
            batch: 0,
            report_secs: 0,
            seed: 1,
        }
    }
}

/// Outcome of one load generation run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub offered_rps: f64,
    pub sent: u64,
    pub ok: u64,
    pub overloaded: u64,
    pub app_errors: u64,
    /// Transport/framing failures — must be zero against a healthy server.
    pub protocol_errors: u64,
    pub wall: Duration,
    /// Client-observed latency from each request's scheduled arrival.
    pub latency: HistSnapshot,
    /// Server-side aggregated metrics fetched after the run.
    pub server: Option<MetricsWire>,
}

impl LoadReport {
    pub fn achieved_rps(&self) -> f64 {
        if self.wall.as_secs_f64() <= 0.0 {
            0.0
        } else {
            self.ok as f64 / self.wall.as_secs_f64()
        }
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "offered {:.1} req/s -> completed {} ok / {} overloaded / {} app errors / \
             {} protocol errors in {:.2} s\n\
             throughput {:.1} req/s  latency p50={:.0}us p95={:.0}us p99={:.0}us mean={:.0}us",
            self.offered_rps,
            self.ok,
            self.overloaded,
            self.app_errors,
            self.protocol_errors,
            self.wall.as_secs_f64(),
            self.achieved_rps(),
            self.latency.percentile_us(50.0),
            self.latency.percentile_us(95.0),
            self.latency.percentile_us(99.0),
            self.latency.mean_us(),
        );
        if let Some(m) = &self.server {
            s.push_str("\nserver: ");
            s.push_str(&m.report());
        }
        s
    }
}

struct Counters {
    next: AtomicUsize,
    ok: AtomicU64,
    overloaded: AtomicU64,
    app_errors: AtomicU64,
    protocol_errors: AtomicU64,
}

/// Run the load generator against a serve endpoint. Warms every session
/// with one learned way first so classification traffic is always valid
/// (batch mode is session-less and skips the warmup).
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport> {
    if cfg.rps <= 0.0 {
        bail!("--rps must be positive");
    }
    if cfg.sessions == 0 && cfg.batch == 0 {
        bail!("--sessions must be at least 1");
    }
    if !(0.0..=1.0).contains(&cfg.learn_frac) {
        bail!("--learn-frac must be in [0, 1]");
    }
    if cfg.pipeline == 0 {
        bail!("--pipeline must be at least 1");
    }
    if cfg.batch > crate::serve::proto::MAX_LIST {
        bail!(
            "--batch must be at most {} (the protocol's list bound)",
            crate::serve::proto::MAX_LIST
        );
    }

    // ---- probe + session warmup -----------------------------------------
    let mut probe = Client::with_config(
        &cfg.addr,
        ClientConfig { timeout: Duration::from_secs(30), ..Default::default() },
    )
    .context("connecting to serve endpoint")?;
    let health = probe.health().context("health probe")?;
    let input_len = health.input_len as usize;
    let mut rng = Rng::new(cfg.seed);
    if cfg.batch == 0 {
        for session in 1..=cfg.sessions {
            let shots: Vec<Vec<u8>> = (0..cfg.shots.max(1))
                .map(|_| rand_input(&mut rng, input_len))
                .collect();
            let mut warmed = false;
            for _ in 0..50 {
                match probe.call(&WireRequest::LearnWay { session, shots: shots.clone() }) {
                    Ok(WireResponse::Error { code: ErrorCode::Overloaded, .. }) => {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Ok(WireResponse::Error { code, message }) => {
                        bail!("warming session {session} failed ({code:?}): {message}");
                    }
                    Ok(_) => {
                        warmed = true;
                        break;
                    }
                    Err(e) => return Err(e).context("warming sessions"),
                }
            }
            if !warmed {
                bail!("could not warm session {session}: server persistently overloaded");
            }
        }
    }

    // ---- pre-draw the open-loop arrival schedule ------------------------
    let mut schedule = Vec::new();
    let mut t = 0.0f64;
    let horizon = cfg.duration.as_secs_f64();
    loop {
        // Exponential inter-arrival: -ln(U)/rate.
        let u = (1.0 - rng.uniform()).max(f64::MIN_POSITIVE);
        t += -u.ln() / cfg.rps;
        if t >= horizon {
            break;
        }
        schedule.push(Duration::from_secs_f64(t));
    }
    let schedule = Arc::new(schedule);

    let counters = Arc::new(Counters {
        next: AtomicUsize::new(0),
        ok: AtomicU64::new(0),
        overloaded: AtomicU64::new(0),
        app_errors: AtomicU64::new(0),
        protocol_errors: AtomicU64::new(0),
    });
    let hist = Arc::new(LatencyHistogram::new());

    // ---- drain the schedule over N connections --------------------------
    let start = Instant::now();

    // Optional in-flight progress reporter: interval percentiles come
    // from snapshot deltas, so each line describes only its own window.
    let stop = Arc::new(AtomicBool::new(false));
    let reporter = if cfg.report_secs > 0 {
        let counters = counters.clone();
        let hist = hist.clone();
        let stop = stop.clone();
        let period = Duration::from_secs(cfg.report_secs);
        let total = schedule.len();
        Some(
            std::thread::Builder::new()
                .name("loadgen-report".to_string())
                .spawn(move || {
                    let mut prev = hist.snapshot();
                    let mut last = Instant::now();
                    while !stop.load(Ordering::Relaxed) {
                        std::thread::sleep(Duration::from_millis(100));
                        if last.elapsed() < period {
                            continue;
                        }
                        let snap = hist.snapshot();
                        let delta = snap.delta(&prev);
                        let secs = last.elapsed().as_secs_f64().max(1e-9);
                        let sent = counters.next.load(Ordering::Relaxed).min(total);
                        eprintln!(
                            "[loadgen] sent {sent}/{total}  last {secs:.1}s: \
                             {:.1} done/s p50={:.0}us p95={:.0}us p99={:.0}us",
                            delta.count as f64 / secs,
                            delta.percentile_us(50.0),
                            delta.percentile_us(95.0),
                            delta.percentile_us(99.0),
                        );
                        prev = snap;
                        last = Instant::now();
                    }
                })
                .context("spawning loadgen reporter")?,
        )
    } else {
        None
    };

    let mut workers = Vec::new();
    for wid in 0..cfg.connections.max(1) {
        let schedule = schedule.clone();
        let counters = counters.clone();
        let hist = hist.clone();
        let addr = cfg.addr.clone();
        let (seed, sessions, learn_frac, shots, batch, depth) = (
            cfg.seed,
            cfg.sessions,
            cfg.learn_frac,
            cfg.shots.max(1),
            cfg.batch,
            cfg.pipeline.max(1),
        );
        workers.push(
            std::thread::Builder::new()
                .name(format!("loadgen-{wid}"))
                .spawn(move || -> Result<()> {
                    let mut client = Client::connect(&addr)?;
                    // Per-arrival deterministic op stream.
                    let build = |i: usize| -> WireRequest {
                        let mut op_rng =
                            Rng::new(seed ^ (i as u64).wrapping_mul(0xA24B_AED4_963E_E407));
                        if batch > 0 {
                            WireRequest::ClassifyBatch {
                                inputs: (0..batch)
                                    .map(|_| rand_input(&mut op_rng, input_len))
                                    .collect(),
                            }
                        } else {
                            let session = 1 + op_rng.below(sessions);
                            if op_rng.uniform() < learn_frac {
                                WireRequest::LearnWay {
                                    session,
                                    shots: (0..shots)
                                        .map(|_| rand_input(&mut op_rng, input_len))
                                        .collect(),
                                }
                            } else {
                                WireRequest::ClassifySession {
                                    session,
                                    input: rand_input(&mut op_rng, input_len),
                                }
                            }
                        }
                    };
                    if depth <= 1 {
                        // Classic blocking path (with the client's
                        // reconnect/retry discipline).
                        loop {
                            let i = counters.next.fetch_add(1, Ordering::Relaxed);
                            if i >= schedule.len() {
                                return Ok(());
                            }
                            let due = start + schedule[i];
                            let now = Instant::now();
                            if due > now {
                                std::thread::sleep(due - now);
                            }
                            let result = client.call(&build(i));
                            // Open-loop latency: from scheduled arrival.
                            hist.record(due.elapsed());
                            record_result(&result, &counters);
                        }
                    }
                    // Pipelined path: keep up to `depth` requests in
                    // flight, draining the oldest when the window is full.
                    let mut inflight: VecDeque<(u64, Instant)> = VecDeque::new();
                    loop {
                        let i = counters.next.fetch_add(1, Ordering::Relaxed);
                        if i >= schedule.len() {
                            break;
                        }
                        let due = start + schedule[i];
                        while inflight.len() >= depth {
                            drain_one(&mut client, &mut inflight, &hist, &counters);
                        }
                        // Use idle time before the next arrival to collect
                        // responses that have already arrived, so their
                        // recorded latency reflects the server rather than
                        // client-side hold time (at low rates the window
                        // would otherwise only drain when full — up to
                        // depth x gap later). Deadline-bounded: a slow
                        // response never stalls the arrival schedule.
                        while let Some(&(id, d)) = inflight.front() {
                            if Instant::now() >= due {
                                break;
                            }
                            match client.wait_until(id, due) {
                                Ok(Some(resp)) => {
                                    inflight.pop_front();
                                    hist.record(d.elapsed());
                                    record_result(&Ok(resp), &counters);
                                }
                                Ok(None) => break, // deadline reached
                                Err(e) => {
                                    inflight.pop_front();
                                    hist.record(d.elapsed());
                                    record_result(&Err(e), &counters);
                                }
                            }
                        }
                        let now = Instant::now();
                        if due > now {
                            std::thread::sleep(due - now);
                        }
                        match client.submit(&build(i)) {
                            Ok(id) => inflight.push_back((id, due)),
                            Err(_) => {
                                // The failed submit and every request lost
                                // with the connection count as protocol
                                // errors, latencies from their dues.
                                hist.record(due.elapsed());
                                counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                                for (_, d) in inflight.drain(..) {
                                    hist.record(d.elapsed());
                                    counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                        }
                    }
                    while !inflight.is_empty() {
                        drain_one(&mut client, &mut inflight, &hist, &counters);
                    }
                    Ok(())
                })
                .context("spawning loadgen worker")?,
        );
    }
    // Stop the reporter before surfacing any worker failure, so an error
    // return never leaks a thread printing into a dead run.
    let mut worker_err: Option<anyhow::Error> = None;
    for w in workers {
        match w.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) if worker_err.is_none() => {
                worker_err = Some(e.context("loadgen worker failed"));
            }
            Ok(Err(_)) => {}
            Err(_) if worker_err.is_none() => {
                worker_err = Some(anyhow::anyhow!("loadgen worker panicked"));
            }
            Err(_) => {}
        }
    }
    stop.store(true, Ordering::Relaxed);
    if let Some(r) = reporter {
        let _ = r.join();
    }
    if let Some(e) = worker_err {
        return Err(e);
    }
    let wall = start.elapsed();

    let server = probe.metrics().ok();
    Ok(LoadReport {
        offered_rps: cfg.rps,
        sent: schedule.len() as u64,
        ok: counters.ok.load(Ordering::Relaxed),
        overloaded: counters.overloaded.load(Ordering::Relaxed),
        app_errors: counters.app_errors.load(Ordering::Relaxed),
        protocol_errors: counters.protocol_errors.load(Ordering::Relaxed),
        wall,
        latency: hist.snapshot(),
        server,
    })
}

/// Wait for the oldest in-flight request and account its outcome.
fn drain_one(
    client: &mut Client,
    inflight: &mut VecDeque<(u64, Instant)>,
    hist: &LatencyHistogram,
    counters: &Counters,
) {
    if let Some((id, due)) = inflight.pop_front() {
        let result = client.wait(id);
        hist.record(due.elapsed());
        record_result(&result, counters);
    }
}

/// Account one response. A `ReplyBatch` counts as one frame: overloaded if
/// any window was shed, an app error if any window failed, ok otherwise.
fn record_result(result: &Result<WireResponse>, counters: &Counters) {
    let bucket = match result {
        Ok(WireResponse::ReplyBatch(items)) => {
            if items
                .iter()
                .any(|it| matches!(it, BatchItem::Error { code: ErrorCode::Overloaded, .. }))
            {
                &counters.overloaded
            } else if items.iter().any(|it| matches!(it, BatchItem::Error { .. })) {
                &counters.app_errors
            } else {
                &counters.ok
            }
        }
        _ => match Outcome::of(result) {
            Outcome::Ok => &counters.ok,
            Outcome::Overloaded => &counters.overloaded,
            Outcome::AppError => &counters.app_errors,
            Outcome::ProtocolError => &counters.protocol_errors,
        },
    };
    bucket.fetch_add(1, Ordering::Relaxed);
}

fn rand_input(rng: &mut Rng, len: usize) -> Vec<u8> {
    (0..len).map(|_| rng.below(16) as u8).collect()
}

// ---------------------------------------------------------------------------
// Streaming mode
// ---------------------------------------------------------------------------

/// Session-id base for stream sessions, so a streaming run never collides
/// with request-mode warmed sessions on the same server.
const STREAM_SESSION_BASE: u64 = 1 << 40;

/// Streaming load configuration: one stream session per connection.
#[derive(Debug, Clone)]
pub struct StreamLoadConfig {
    pub addr: String,
    /// Concurrent stream sessions (one connection each).
    pub connections: usize,
    pub duration: Duration,
    /// Timesteps pushed per chunk.
    pub chunk: usize,
    /// Decision stride in timesteps; 0 means one window (non-overlapping).
    pub hop: usize,
    /// Input sample rate in timesteps/s each session is paced to;
    /// 0 = free-running (push as fast as the server accepts).
    pub pace_hz: f64,
    pub seed: u64,
}

impl Default for StreamLoadConfig {
    fn default() -> Self {
        StreamLoadConfig {
            addr: "127.0.0.1:7070".to_string(),
            connections: 4,
            duration: Duration::from_secs(10),
            chunk: 64,
            hop: 0,
            pace_hz: 0.0,
            seed: 1,
        }
    }
}

/// Outcome of one streaming load run.
#[derive(Debug, Clone)]
pub struct StreamReport {
    pub sessions: usize,
    /// Window / hop geometry the server accepted (timesteps).
    pub window: usize,
    pub hop: usize,
    pub chunk: usize,
    /// Chunks accepted (answered with `StreamDecisions`).
    pub ok: u64,
    /// Chunks shed by backpressure — the stream *skips* those samples.
    pub overloaded: u64,
    pub app_errors: u64,
    /// Transport/framing failures — must be zero against a healthy server.
    pub protocol_errors: u64,
    /// Per-window decisions received across all sessions.
    pub decisions: u64,
    pub wall: Duration,
    /// Latency of each chunk push, from its scheduled send time.
    pub chunk_latency: HistSnapshot,
    /// Latency of each *decision*, from the scheduled send of the chunk
    /// that completed its window.
    pub decision_latency: HistSnapshot,
    /// Server-side aggregated metrics fetched after the run.
    pub server: Option<MetricsWire>,
}

impl StreamReport {
    pub fn decisions_per_sec(&self) -> f64 {
        if self.wall.as_secs_f64() <= 0.0 {
            0.0
        } else {
            self.decisions as f64 / self.wall.as_secs_f64()
        }
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "streaming: {} session(s), window {} hop {} chunk {} steps -> \
             {} chunks ok / {} overloaded / {} app errors / {} protocol errors in {:.2} s\n\
             decisions {} ({:.1}/s)\n\
             chunk latency    p50={:.0}us p95={:.0}us p99={:.0}us mean={:.0}us\n\
             decision latency p50={:.0}us p95={:.0}us p99={:.0}us mean={:.0}us",
            self.sessions,
            self.window,
            self.hop,
            self.chunk,
            self.ok,
            self.overloaded,
            self.app_errors,
            self.protocol_errors,
            self.wall.as_secs_f64(),
            self.decisions,
            self.decisions_per_sec(),
            self.chunk_latency.percentile_us(50.0),
            self.chunk_latency.percentile_us(95.0),
            self.chunk_latency.percentile_us(99.0),
            self.chunk_latency.mean_us(),
            self.decision_latency.percentile_us(50.0),
            self.decision_latency.percentile_us(95.0),
            self.decision_latency.percentile_us(99.0),
            self.decision_latency.mean_us(),
        );
        if let Some(m) = &self.server {
            s.push_str("\nserver: ");
            s.push_str(&m.report());
        }
        s
    }
}

struct StreamCounters {
    ok: AtomicU64,
    overloaded: AtomicU64,
    app_errors: AtomicU64,
    protocol_errors: AtomicU64,
    decisions: AtomicU64,
}

/// Run the streaming load generator: each connection opens its own stream
/// session and pushes `chunk`-timestep chunks until the duration elapses,
/// then closes its stream.
pub fn run_stream(cfg: &StreamLoadConfig) -> Result<StreamReport> {
    if cfg.chunk == 0 {
        bail!("--chunk must be positive");
    }
    if cfg.connections == 0 {
        bail!("--connections must be at least 1");
    }
    if cfg.pace_hz < 0.0 {
        bail!("--pace-hz must be non-negative");
    }
    let mut probe = Client::with_config(
        &cfg.addr,
        ClientConfig { timeout: Duration::from_secs(30), ..Default::default() },
    )
    .context("connecting to serve endpoint")?;
    let health = probe.health().context("health probe")?;
    if health.window == 0 || health.channels == 0 {
        bail!("server does not report stream geometry (pre-v2 server?)");
    }
    let window = health.window as usize;
    let channels = health.channels as usize;
    let hop = if cfg.hop == 0 { window } else { cfg.hop };

    let counters = Arc::new(StreamCounters {
        ok: AtomicU64::new(0),
        overloaded: AtomicU64::new(0),
        app_errors: AtomicU64::new(0),
        protocol_errors: AtomicU64::new(0),
        decisions: AtomicU64::new(0),
    });
    let chunk_hist = Arc::new(LatencyHistogram::new());
    let decision_hist = Arc::new(LatencyHistogram::new());

    let start = Instant::now();
    let deadline = start + cfg.duration;
    let mut workers = Vec::new();
    for wid in 0..cfg.connections {
        let counters = counters.clone();
        let chunk_hist = chunk_hist.clone();
        let decision_hist = decision_hist.clone();
        let addr = cfg.addr.clone();
        let (seed, chunk, pace_hz) = (cfg.seed, cfg.chunk, cfg.pace_hz);
        workers.push(
            std::thread::Builder::new()
                .name(format!("streamgen-{wid}"))
                .spawn(move || -> Result<()> {
                    let mut client = Client::connect(&addr)?;
                    let session = STREAM_SESSION_BASE + wid as u64;
                    client
                        .stream_open(session, hop as u32)
                        .context("opening stream session")?;
                    let period = if pace_hz > 0.0 {
                        Some(Duration::from_secs_f64(chunk as f64 / pace_hz))
                    } else {
                        None
                    };
                    let mut rng =
                        Rng::new(seed ^ (wid as u64).wrapping_mul(0xD1B5_4A32_D192_ED03));
                    let mut n = 0u64;
                    loop {
                        let due = match period {
                            Some(p) => start + p.mul_f64(n as f64),
                            None => Instant::now(),
                        };
                        if due >= deadline || Instant::now() >= deadline {
                            break;
                        }
                        let now = Instant::now();
                        if due > now {
                            std::thread::sleep(due - now);
                        }
                        let samples = rand_input(&mut rng, chunk * channels);
                        let result = client.call(&WireRequest::StreamPush { session, samples });
                        let lat = due.elapsed();
                        chunk_hist.record(lat);
                        match &result {
                            Ok(WireResponse::StreamDecisions(ds)) => {
                                counters.ok.fetch_add(1, Ordering::Relaxed);
                                counters.decisions.fetch_add(ds.len() as u64, Ordering::Relaxed);
                                for _ in ds {
                                    decision_hist.record(lat);
                                }
                            }
                            _ => match Outcome::of(&result) {
                                Outcome::Overloaded => {
                                    counters.overloaded.fetch_add(1, Ordering::Relaxed);
                                }
                                Outcome::ProtocolError => {
                                    counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                                }
                                _ => {
                                    counters.app_errors.fetch_add(1, Ordering::Relaxed);
                                }
                            },
                        }
                        n += 1;
                    }
                    let _ = client.stream_close(session);
                    Ok(())
                })
                .context("spawning stream worker")?,
        );
    }
    for w in workers {
        match w.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(e.context("stream worker failed")),
            Err(_) => bail!("stream worker panicked"),
        }
    }
    let wall = start.elapsed();

    let server = probe.metrics().ok();
    Ok(StreamReport {
        sessions: cfg.connections,
        window,
        hop,
        chunk: cfg.chunk,
        ok: counters.ok.load(Ordering::Relaxed),
        overloaded: counters.overloaded.load(Ordering::Relaxed),
        app_errors: counters.app_errors.load(Ordering::Relaxed),
        protocol_errors: counters.protocol_errors.load(Ordering::Relaxed),
        decisions: counters.decisions.load(Ordering::Relaxed),
        wall,
        chunk_latency: chunk_hist.snapshot(),
        decision_latency: decision_hist.snapshot(),
        server,
    })
}

// ---------------------------------------------------------------------------
// Continual-learning mode
// ---------------------------------------------------------------------------

/// Session-id base for CL sessions, disjoint from both request-mode warmed
/// sessions and stream sessions on the same server.
const CL_SESSION_BASE: u64 = 1 << 41;

/// Continual-learning load configuration: one growing-way session per
/// connection.
#[derive(Debug, Clone)]
pub struct ClLoadConfig {
    pub addr: String,
    /// Concurrent CL sessions (one connection each).
    pub connections: usize,
    pub duration: Duration,
    /// Target ways per session; reaching `ways` x `shots_per_way` evicts
    /// the session and starts growing a fresh one.
    pub ways: usize,
    /// Target shots per way (grown one shot at a time: the first via
    /// `LearnWay`, the rest via `AddShots`).
    pub shots_per_way: usize,
    /// Fraction of ops that are `ClassifySession` queries (the rest are
    /// learning updates).
    pub classify_frac: f64,
    pub seed: u64,
}

impl Default for ClLoadConfig {
    fn default() -> Self {
        ClLoadConfig {
            addr: "127.0.0.1:7070".to_string(),
            connections: 4,
            duration: Duration::from_secs(10),
            ways: 50,
            shots_per_way: 10,
            classify_frac: 0.5,
            seed: 1,
        }
    }
}

/// Outcome of one continual-learning load run.
#[derive(Debug, Clone)]
pub struct ClLoadReport {
    pub sessions: usize,
    pub ways_target: usize,
    pub shots_target: usize,
    /// `LearnWay` ops that succeeded (new ways opened).
    pub learns: u64,
    /// `AddShots` ops that succeeded (prototype updates).
    pub adds: u64,
    /// `ClassifySession` ops that succeeded.
    pub classifies: u64,
    /// Sessions that reached their ways x shots target and were evicted
    /// to start a fresh trajectory.
    pub completed_trajectories: u64,
    pub overloaded: u64,
    pub app_errors: u64,
    /// Transport/framing failures — must be zero against a healthy server.
    pub protocol_errors: u64,
    pub wall: Duration,
    /// Per-op latency, from each op's send (closed loop: a CL update
    /// depends on the previous op's outcome, so arrivals cannot be
    /// pre-drawn like the open-loop request mode).
    pub learn_latency: HistSnapshot,
    pub add_latency: HistSnapshot,
    pub classify_latency: HistSnapshot,
    /// Server-side aggregated metrics fetched after the run.
    pub server: Option<MetricsWire>,
}

impl ClLoadReport {
    /// Learning updates (learn + add) per second.
    pub fn updates_per_sec(&self) -> f64 {
        if self.wall.as_secs_f64() <= 0.0 {
            0.0
        } else {
            (self.learns + self.adds) as f64 / self.wall.as_secs_f64()
        }
    }

    pub fn report(&self) -> String {
        let pct = |h: &HistSnapshot| {
            format!(
                "p50={:.0}us p95={:.0}us p99={:.0}us mean={:.0}us",
                h.percentile_us(50.0),
                h.percentile_us(95.0),
                h.percentile_us(99.0),
                h.mean_us(),
            )
        };
        let mut s = format!(
            "cl: {} session(s) growing to {} ways x {} shots -> \
             {} learns / {} adds / {} classifies / {} trajectories completed\n\
             {} overloaded / {} app errors / {} protocol errors in {:.2} s \
             ({:.1} updates/s)\n\
             learn latency    {}\nadd latency      {}\nclassify latency {}",
            self.sessions,
            self.ways_target,
            self.shots_target,
            self.learns,
            self.adds,
            self.classifies,
            self.completed_trajectories,
            self.overloaded,
            self.app_errors,
            self.protocol_errors,
            self.wall.as_secs_f64(),
            self.updates_per_sec(),
            pct(&self.learn_latency),
            pct(&self.add_latency),
            pct(&self.classify_latency),
        );
        if let Some(m) = &self.server {
            s.push_str("\nserver: ");
            s.push_str(&m.report());
        }
        s
    }
}

struct ClCounters {
    learns: AtomicU64,
    adds: AtomicU64,
    classifies: AtomicU64,
    completed: AtomicU64,
    overloaded: AtomicU64,
    app_errors: AtomicU64,
    protocol_errors: AtomicU64,
}

/// Run the continual-learning load generator: each connection grows its
/// own session one shot at a time — a new way via `LearnWay` when every
/// existing way is full (or none exists), otherwise `AddShots` into the
/// first unfilled way — interleaved with `ClassifySession` queries, until
/// the duration elapses. A session that reaches its full ways x shots
/// trajectory is evicted and regrown from scratch.
pub fn run_cl(cfg: &ClLoadConfig) -> Result<ClLoadReport> {
    if cfg.connections == 0 {
        bail!("--connections must be at least 1");
    }
    if cfg.ways == 0 || cfg.shots_per_way == 0 {
        bail!("--ways and --shots must be positive");
    }
    if !(0.0..=1.0).contains(&cfg.classify_frac) {
        bail!("--classify-frac must be in [0, 1]");
    }
    let mut probe = Client::with_config(
        &cfg.addr,
        ClientConfig { timeout: Duration::from_secs(30), ..Default::default() },
    )
    .context("connecting to serve endpoint")?;
    let health = probe.health().context("health probe")?;
    let input_len = health.input_len as usize;

    let counters = Arc::new(ClCounters {
        learns: AtomicU64::new(0),
        adds: AtomicU64::new(0),
        classifies: AtomicU64::new(0),
        completed: AtomicU64::new(0),
        overloaded: AtomicU64::new(0),
        app_errors: AtomicU64::new(0),
        protocol_errors: AtomicU64::new(0),
    });
    let learn_hist = Arc::new(LatencyHistogram::new());
    let add_hist = Arc::new(LatencyHistogram::new());
    let classify_hist = Arc::new(LatencyHistogram::new());

    let start = Instant::now();
    let deadline = start + cfg.duration;
    let mut workers = Vec::new();
    for wid in 0..cfg.connections {
        let counters = counters.clone();
        let learn_hist = learn_hist.clone();
        let add_hist = add_hist.clone();
        let classify_hist = classify_hist.clone();
        let addr = cfg.addr.clone();
        let (seed, ways_target, shots_target, classify_frac) =
            (cfg.seed, cfg.ways, cfg.shots_per_way, cfg.classify_frac);
        workers.push(
            std::thread::Builder::new()
                .name(format!("clgen-{wid}"))
                .spawn(move || -> Result<()> {
                    let mut client = Client::connect(&addr)?;
                    let session = CL_SESSION_BASE + wid as u64;
                    // Start from a clean slate even if an earlier run left
                    // this session behind on the server.
                    let _ = client.evict_session(session);
                    let mut rng =
                        Rng::new(seed ^ (wid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
                    // Client-side view of the growing head, resynced from
                    // each op's reply.
                    let mut shots_per_way: Vec<usize> = Vec::new();
                    while Instant::now() < deadline {
                        let classify = !shots_per_way.is_empty() && rng.uniform() < classify_frac;
                        if classify {
                            let t0 = Instant::now();
                            let result = client.call(&WireRequest::ClassifySession {
                                session,
                                input: rand_input(&mut rng, input_len),
                            });
                            classify_hist.record(t0.elapsed());
                            match Outcome::of(&result) {
                                Outcome::Ok => {
                                    counters.classifies.fetch_add(1, Ordering::Relaxed);
                                }
                                Outcome::Overloaded => {
                                    counters.overloaded.fetch_add(1, Ordering::Relaxed);
                                }
                                Outcome::AppError => {
                                    counters.app_errors.fetch_add(1, Ordering::Relaxed);
                                }
                                Outcome::ProtocolError => {
                                    counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            continue;
                        }
                        // Learning update: deepen the first unfilled way,
                        // else open a new way, else the trajectory is
                        // complete — evict and regrow.
                        let unfilled = shots_per_way.iter().position(|&s| s < shots_target);
                        let (req, is_add, way) = match unfilled {
                            Some(way) => (
                                WireRequest::AddShots {
                                    session,
                                    way: way as u64,
                                    shots: vec![rand_input(&mut rng, input_len)],
                                },
                                true,
                                way,
                            ),
                            None if shots_per_way.len() < ways_target => (
                                WireRequest::LearnWay {
                                    session,
                                    shots: vec![rand_input(&mut rng, input_len)],
                                },
                                false,
                                shots_per_way.len(),
                            ),
                            None => {
                                counters.completed.fetch_add(1, Ordering::Relaxed);
                                let _ = client.evict_session(session);
                                shots_per_way.clear();
                                continue;
                            }
                        };
                        let t0 = Instant::now();
                        let result = client.call(&req);
                        let hist = if is_add { &add_hist } else { &learn_hist };
                        hist.record(t0.elapsed());
                        match Outcome::of(&result) {
                            Outcome::Ok => {
                                if is_add {
                                    shots_per_way[way] += 1;
                                    counters.adds.fetch_add(1, Ordering::Relaxed);
                                } else {
                                    shots_per_way.push(1);
                                    counters.learns.fetch_add(1, Ordering::Relaxed);
                                }
                            }
                            Outcome::Overloaded => {
                                counters.overloaded.fetch_add(1, Ordering::Relaxed);
                            }
                            Outcome::AppError => {
                                // The session was LRU-evicted under
                                // cross-talk, or the server's way budget
                                // is smaller than the --ways target
                                // (WaysExhausted): evict and regrow from
                                // scratch instead of re-issuing the same
                                // doomed op in a hot loop.
                                counters.app_errors.fetch_add(1, Ordering::Relaxed);
                                let _ = client.evict_session(session);
                                shots_per_way.clear();
                            }
                            Outcome::ProtocolError => {
                                counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                    let _ = client.evict_session(session);
                    Ok(())
                })
                .context("spawning cl worker")?,
        );
    }
    for w in workers {
        match w.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(e.context("cl worker failed")),
            Err(_) => bail!("cl worker panicked"),
        }
    }
    let wall = start.elapsed();

    let server = probe.metrics().ok();
    Ok(ClLoadReport {
        sessions: cfg.connections,
        ways_target: cfg.ways,
        shots_target: cfg.shots_per_way,
        learns: counters.learns.load(Ordering::Relaxed),
        adds: counters.adds.load(Ordering::Relaxed),
        classifies: counters.classifies.load(Ordering::Relaxed),
        completed_trajectories: counters.completed.load(Ordering::Relaxed),
        overloaded: counters.overloaded.load(Ordering::Relaxed),
        app_errors: counters.app_errors.load(Ordering::Relaxed),
        protocol_errors: counters.protocol_errors.load(Ordering::Relaxed),
        wall,
        learn_latency: learn_hist.snapshot(),
        add_latency: add_hist.snapshot(),
        classify_latency: classify_hist.snapshot(),
        server,
    })
}

// ---------------------------------------------------------------------------
// High-fanout mode
// ---------------------------------------------------------------------------

/// High-fanout load configuration: many concurrent pipelined connections,
/// few requests each.
#[derive(Debug, Clone)]
pub struct FanoutConfig {
    pub addr: String,
    /// Concurrent connections, all held open for the whole run.
    pub connections: usize,
    /// Requests pipelined on every connection per wave.
    pub per_conn: usize,
    /// Submit-everywhere-then-drain waves over the open connections.
    pub waves: usize,
    pub seed: u64,
}

impl Default for FanoutConfig {
    fn default() -> Self {
        FanoutConfig {
            addr: "127.0.0.1:7070".to_string(),
            connections: 1024,
            per_conn: 2,
            waves: 2,
            seed: 1,
        }
    }
}

/// Outcome of one fan-out run.
#[derive(Debug, Clone)]
pub struct FanoutReport {
    pub connections: usize,
    pub per_conn: usize,
    pub waves: usize,
    /// Requests actually submitted (successful `send` calls) — failed
    /// sends count only as `protocol_errors`, so `ok + overloaded +
    /// app_errors` can be compared against this even on a lossy run.
    pub sent: u64,
    pub ok: u64,
    pub overloaded: u64,
    pub app_errors: u64,
    /// Transport/framing failures — must be zero against a healthy server.
    pub protocol_errors: u64,
    pub wall: Duration,
    /// Per-request latency from each request's submit.
    pub latency: HistSnapshot,
    /// Server-side aggregated metrics fetched after the run.
    pub server: Option<MetricsWire>,
}

impl FanoutReport {
    /// Completed responses (ok, shed, or app-failed — all full round
    /// trips) per second. Shed responses count: under deliberate
    /// overcommit the turnaround rate is the scaling signal, not the
    /// admission rate.
    pub fn responses_per_sec(&self) -> f64 {
        let done = self.ok + self.overloaded + self.app_errors;
        if self.wall.as_secs_f64() <= 0.0 {
            0.0
        } else {
            done as f64 / self.wall.as_secs_f64()
        }
    }

    pub fn p99_us(&self) -> f64 {
        self.latency.percentile_us(99.0)
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "fanout: {} connection(s) x {} in flight x {} wave(s) -> \
             {} ok / {} overloaded / {} app errors / {} protocol errors in {:.2} s\n\
             turnaround {:.1} resp/s  latency p50={:.0}us p95={:.0}us p99={:.0}us mean={:.0}us",
            self.connections,
            self.per_conn,
            self.waves,
            self.ok,
            self.overloaded,
            self.app_errors,
            self.protocol_errors,
            self.wall.as_secs_f64(),
            self.responses_per_sec(),
            self.latency.percentile_us(50.0),
            self.latency.percentile_us(95.0),
            self.p99_us(),
            self.latency.mean_us(),
        );
        if let Some(m) = &self.server {
            s.push_str("\nserver: ");
            s.push_str(&m.report());
        }
        s
    }
}

/// Run the fan-out load generator: open `connections` sockets, then in
/// each wave submit `per_conn` pipelined classifications on *every*
/// connection before draining any — so the server holds the full
/// connection count with traffic in flight on all of them at once.
pub fn run_fanout(cfg: &FanoutConfig) -> Result<FanoutReport> {
    if cfg.connections == 0 {
        bail!("--connections must be at least 1");
    }
    if cfg.per_conn == 0 {
        bail!("--per-conn must be at least 1");
    }
    if cfg.waves == 0 {
        bail!("--waves must be at least 1");
    }
    // Thousands of sockets need headroom over the usual 1024 soft cap.
    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    let _ = crate::serve::sys::raise_nofile_limit();

    let mut probe = Client::with_config(
        &cfg.addr,
        ClientConfig { timeout: Duration::from_secs(30), ..Default::default() },
    )
    .context("connecting to serve endpoint")?;
    let health = probe.health().context("health probe")?;
    let input_len = health.input_len as usize;
    let mut rng = Rng::new(cfg.seed);

    let mut clients = Vec::with_capacity(cfg.connections);
    for i in 0..cfg.connections {
        let c = Client::connect(&cfg.addr)
            .with_context(|| format!("opening fanout connection {i} of {}", cfg.connections))?;
        clients.push(c);
    }

    let counters = Counters {
        next: AtomicUsize::new(0),
        ok: AtomicU64::new(0),
        overloaded: AtomicU64::new(0),
        app_errors: AtomicU64::new(0),
        protocol_errors: AtomicU64::new(0),
    };
    let hist = LatencyHistogram::new();
    let start = Instant::now();
    let mut sent: u64 = 0;
    for _ in 0..cfg.waves {
        let mut tickets: Vec<Vec<(u64, Instant)>> = Vec::with_capacity(clients.len());
        for client in clients.iter_mut() {
            let mut batch = Vec::with_capacity(cfg.per_conn);
            for _ in 0..cfg.per_conn {
                let req = WireRequest::Classify { input: rand_input(&mut rng, input_len) };
                match client.send(&req) {
                    Ok(t) => batch.push((t.id(), Instant::now())),
                    Err(_) => {
                        counters.protocol_errors.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            sent += batch.len() as u64;
            tickets.push(batch);
        }
        // Every connection now has its full window in flight; drain.
        for (client, batch) in clients.iter_mut().zip(tickets) {
            for (id, t0) in batch {
                let result = client.wait(id);
                hist.record(t0.elapsed());
                record_result(&result, &counters);
            }
        }
    }
    let wall = start.elapsed();

    let server = probe.metrics().ok();
    Ok(FanoutReport {
        connections: cfg.connections,
        per_conn: cfg.per_conn,
        waves: cfg.waves,
        sent,
        ok: counters.ok.load(Ordering::Relaxed),
        overloaded: counters.overloaded.load(Ordering::Relaxed),
        app_errors: counters.app_errors.load(Ordering::Relaxed),
        protocol_errors: counters.protocol_errors.load(Ordering::Relaxed),
        wall,
        latency: hist.snapshot(),
        server,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation() {
        let mut cfg = LoadgenConfig { rps: 0.0, ..Default::default() };
        assert!(run(&cfg).is_err());
        cfg.rps = 100.0;
        cfg.learn_frac = 1.5;
        assert!(run(&cfg).is_err());
        cfg.learn_frac = 0.1;
        cfg.sessions = 0;
        assert!(run(&cfg).is_err());
        cfg.sessions = 4;
        cfg.pipeline = 0;
        assert!(run(&cfg).is_err());
        cfg.pipeline = 1;
        cfg.batch = crate::serve::proto::MAX_LIST + 1;
        assert!(run(&cfg).is_err(), "oversized --batch must fail fast");
    }

    #[test]
    fn fanout_config_validation() {
        let mut cfg = FanoutConfig { connections: 0, ..Default::default() };
        assert!(run_fanout(&cfg).is_err());
        cfg.connections = 1;
        cfg.per_conn = 0;
        assert!(run_fanout(&cfg).is_err());
        cfg.per_conn = 1;
        cfg.waves = 0;
        assert!(run_fanout(&cfg).is_err());
    }

    #[test]
    fn fanout_report_formats() {
        let r = FanoutReport {
            connections: 1000,
            per_conn: 2,
            waves: 2,
            sent: 4000,
            ok: 3900,
            overloaded: 100,
            app_errors: 0,
            protocol_errors: 0,
            wall: Duration::from_secs(2),
            latency: HistSnapshot::default(),
            server: None,
        };
        let s = r.report();
        assert!(s.contains("1000 connection(s)"), "{s}");
        assert!(s.contains("0 protocol errors"), "{s}");
        assert!((r.responses_per_sec() - 2000.0).abs() < 1e-9, "shed responses still count");
    }

    #[test]
    fn batch_replies_count_as_one_frame() {
        let counters = Counters {
            next: AtomicUsize::new(0),
            ok: AtomicU64::new(0),
            overloaded: AtomicU64::new(0),
            app_errors: AtomicU64::new(0),
            protocol_errors: AtomicU64::new(0),
        };
        let ok_batch: Result<WireResponse> =
            Ok(WireResponse::ReplyBatch(vec![BatchItem::Reply(Default::default())]));
        record_result(&ok_batch, &counters);
        let shed: Result<WireResponse> = Ok(WireResponse::ReplyBatch(vec![
            BatchItem::Reply(Default::default()),
            BatchItem::Error { code: ErrorCode::Overloaded, message: "full".into() },
        ]));
        record_result(&shed, &counters);
        let failed: Result<WireResponse> = Ok(WireResponse::ReplyBatch(vec![BatchItem::Error {
            code: ErrorCode::App,
            message: "bad window".into(),
        }]));
        record_result(&failed, &counters);
        assert_eq!(counters.ok.load(Ordering::Relaxed), 1);
        assert_eq!(counters.overloaded.load(Ordering::Relaxed), 1);
        assert_eq!(counters.app_errors.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn cl_config_validation() {
        let mut cfg = ClLoadConfig { connections: 0, ..Default::default() };
        assert!(run_cl(&cfg).is_err());
        cfg.connections = 1;
        cfg.ways = 0;
        assert!(run_cl(&cfg).is_err());
        cfg.ways = 2;
        cfg.shots_per_way = 0;
        assert!(run_cl(&cfg).is_err());
        cfg.shots_per_way = 2;
        cfg.classify_frac = 1.5;
        assert!(run_cl(&cfg).is_err());
    }

    #[test]
    fn cl_report_formats() {
        let r = ClLoadReport {
            sessions: 2,
            ways_target: 50,
            shots_target: 10,
            learns: 100,
            adds: 900,
            classifies: 500,
            completed_trajectories: 1,
            overloaded: 0,
            app_errors: 0,
            protocol_errors: 0,
            wall: Duration::from_secs(2),
            learn_latency: HistSnapshot::default(),
            add_latency: HistSnapshot::default(),
            classify_latency: HistSnapshot::default(),
            server: None,
        };
        let s = r.report();
        assert!(s.contains("100 learns"), "{s}");
        assert!(s.contains("900 adds"), "{s}");
        assert!(s.contains("add latency"), "{s}");
        assert!((r.updates_per_sec() - 500.0).abs() < 1e-9);
    }

    #[test]
    fn stream_config_validation() {
        let mut cfg = StreamLoadConfig { chunk: 0, ..Default::default() };
        assert!(run_stream(&cfg).is_err());
        cfg.chunk = 8;
        cfg.connections = 0;
        assert!(run_stream(&cfg).is_err());
        cfg.connections = 1;
        cfg.pace_hz = -1.0;
        assert!(run_stream(&cfg).is_err());
    }

    #[test]
    fn stream_report_formats() {
        let r = StreamReport {
            sessions: 2,
            window: 16,
            hop: 4,
            chunk: 8,
            ok: 10,
            overloaded: 1,
            app_errors: 0,
            protocol_errors: 0,
            decisions: 7,
            wall: Duration::from_secs(1),
            chunk_latency: HistSnapshot::default(),
            decision_latency: HistSnapshot::default(),
            server: None,
        };
        let s = r.report();
        assert!(s.contains("10 chunks ok"), "{s}");
        assert!(s.contains("decision latency"), "{s}");
        assert!((r.decisions_per_sec() - 7.0).abs() < 1e-9);
    }

    #[test]
    fn report_formats() {
        let r = LoadReport {
            offered_rps: 100.0,
            sent: 10,
            ok: 9,
            overloaded: 1,
            app_errors: 0,
            protocol_errors: 0,
            wall: Duration::from_secs(1),
            latency: HistSnapshot::default(),
            server: None,
        };
        let s = r.report();
        assert!(s.contains("9 ok"));
        assert!(s.contains("p99"));
        assert!((r.achieved_rps() - 9.0).abs() < 1e-9);
    }
}
