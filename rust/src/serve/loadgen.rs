//! Open-loop Poisson load generator for the serve layer.
//!
//! Arrival times are pre-drawn from an exponential inter-arrival process at
//! the configured rate and *do not* adapt to response latency (open-loop):
//! if the server falls behind, arrivals queue on the worker threads and the
//! measured latency — taken from each request's **scheduled** arrival time,
//! not its actual send time — faithfully includes that coordination delay.
//! This avoids the closed-loop trap where a slow server throttles its own
//! load and the tail disappears from the histogram.
//!
//! Traffic mix: each arrival is a `LearnWay` with probability `learn_frac`
//! (k random shots on a random session), otherwise a `ClassifySession` on a
//! random pre-warmed session. Sessions span all shards, so a run exercises
//! cross-shard routing by construction.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::coordinator::metrics::{HistSnapshot, LatencyHistogram};
use crate::serve::client::{Client, ClientConfig, Outcome};
use crate::serve::proto::{ErrorCode, MetricsWire, WireRequest, WireResponse};
use crate::util::rng::Rng;

/// Load generator configuration.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    pub addr: String,
    /// Offered load in requests per second (Poisson arrivals).
    pub rps: f64,
    pub duration: Duration,
    /// Fraction of arrivals that are `LearnWay` ops (rest classify).
    pub learn_frac: f64,
    /// Session-id space (1..=sessions), warmed before the run starts.
    pub sessions: u64,
    /// Shots per learn op.
    pub shots: usize,
    /// Worker connections draining the arrival schedule.
    pub connections: usize,
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:7070".to_string(),
            rps: 200.0,
            duration: Duration::from_secs(10),
            learn_frac: 0.05,
            sessions: 16,
            shots: 2,
            connections: 4,
            seed: 1,
        }
    }
}

/// Outcome of one load generation run.
#[derive(Debug, Clone)]
pub struct LoadReport {
    pub offered_rps: f64,
    pub sent: u64,
    pub ok: u64,
    pub overloaded: u64,
    pub app_errors: u64,
    /// Transport/framing failures — must be zero against a healthy server.
    pub protocol_errors: u64,
    pub wall: Duration,
    /// Client-observed latency from each request's scheduled arrival.
    pub latency: HistSnapshot,
    /// Server-side aggregated metrics fetched after the run.
    pub server: Option<MetricsWire>,
}

impl LoadReport {
    pub fn achieved_rps(&self) -> f64 {
        if self.wall.as_secs_f64() <= 0.0 {
            0.0
        } else {
            self.ok as f64 / self.wall.as_secs_f64()
        }
    }

    pub fn report(&self) -> String {
        let mut s = format!(
            "offered {:.1} req/s -> completed {} ok / {} overloaded / {} app errors / \
             {} protocol errors in {:.2} s\n\
             throughput {:.1} req/s  latency p50={:.0}us p95={:.0}us p99={:.0}us mean={:.0}us",
            self.offered_rps,
            self.ok,
            self.overloaded,
            self.app_errors,
            self.protocol_errors,
            self.wall.as_secs_f64(),
            self.achieved_rps(),
            self.latency.percentile_us(50.0),
            self.latency.percentile_us(95.0),
            self.latency.percentile_us(99.0),
            self.latency.mean_us(),
        );
        if let Some(m) = &self.server {
            s.push_str("\nserver: ");
            s.push_str(&m.report());
        }
        s
    }
}

struct Counters {
    next: AtomicUsize,
    ok: AtomicU64,
    overloaded: AtomicU64,
    app_errors: AtomicU64,
    protocol_errors: AtomicU64,
}

/// Run the load generator against a serve endpoint. Warms every session
/// with one learned way first so classification traffic is always valid.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport> {
    if cfg.rps <= 0.0 {
        bail!("--rps must be positive");
    }
    if cfg.sessions == 0 {
        bail!("--sessions must be at least 1");
    }
    if !(0.0..=1.0).contains(&cfg.learn_frac) {
        bail!("--learn-frac must be in [0, 1]");
    }

    // ---- probe + session warmup -----------------------------------------
    let mut probe = Client::with_config(
        &cfg.addr,
        ClientConfig { timeout: Duration::from_secs(30), ..Default::default() },
    )
    .context("connecting to serve endpoint")?;
    let health = probe.health().context("health probe")?;
    let input_len = health.input_len as usize;
    let mut rng = Rng::new(cfg.seed);
    for session in 1..=cfg.sessions {
        let shots: Vec<Vec<u8>> = (0..cfg.shots.max(1))
            .map(|_| rand_input(&mut rng, input_len))
            .collect();
        let mut warmed = false;
        for _ in 0..50 {
            match probe.call(&WireRequest::LearnWay { session, shots: shots.clone() }) {
                Ok(WireResponse::Error { code: ErrorCode::Overloaded, .. }) => {
                    std::thread::sleep(Duration::from_millis(10));
                }
                Ok(WireResponse::Error { code, message }) => {
                    bail!("warming session {session} failed ({code:?}): {message}");
                }
                Ok(_) => {
                    warmed = true;
                    break;
                }
                Err(e) => return Err(e).context("warming sessions"),
            }
        }
        if !warmed {
            bail!("could not warm session {session}: server persistently overloaded");
        }
    }

    // ---- pre-draw the open-loop arrival schedule ------------------------
    let mut schedule = Vec::new();
    let mut t = 0.0f64;
    let horizon = cfg.duration.as_secs_f64();
    loop {
        // Exponential inter-arrival: -ln(U)/rate.
        let u = (1.0 - rng.uniform()).max(f64::MIN_POSITIVE);
        t += -u.ln() / cfg.rps;
        if t >= horizon {
            break;
        }
        schedule.push(Duration::from_secs_f64(t));
    }
    let schedule = Arc::new(schedule);

    let counters = Arc::new(Counters {
        next: AtomicUsize::new(0),
        ok: AtomicU64::new(0),
        overloaded: AtomicU64::new(0),
        app_errors: AtomicU64::new(0),
        protocol_errors: AtomicU64::new(0),
    });
    let hist = Arc::new(LatencyHistogram::new());

    // ---- drain the schedule over N connections --------------------------
    let start = Instant::now();
    let mut workers = Vec::new();
    for wid in 0..cfg.connections.max(1) {
        let schedule = schedule.clone();
        let counters = counters.clone();
        let hist = hist.clone();
        let addr = cfg.addr.clone();
        let (seed, sessions, learn_frac, shots) =
            (cfg.seed, cfg.sessions, cfg.learn_frac, cfg.shots.max(1));
        workers.push(
            std::thread::Builder::new()
                .name(format!("loadgen-{wid}"))
                .spawn(move || -> Result<()> {
                    let mut client = Client::connect(&addr)?;
                    loop {
                        let i = counters.next.fetch_add(1, Ordering::Relaxed);
                        if i >= schedule.len() {
                            return Ok(());
                        }
                        let due = start + schedule[i];
                        let now = Instant::now();
                        if due > now {
                            std::thread::sleep(due - now);
                        }
                        // Per-arrival deterministic op stream.
                        let mut op_rng =
                            Rng::new(seed ^ (i as u64).wrapping_mul(0xA24B_AED4_963E_E407));
                        let session = 1 + op_rng.below(sessions);
                        let req = if op_rng.uniform() < learn_frac {
                            WireRequest::LearnWay {
                                session,
                                shots: (0..shots)
                                    .map(|_| rand_input(&mut op_rng, input_len))
                                    .collect(),
                            }
                        } else {
                            WireRequest::ClassifySession {
                                session,
                                input: rand_input(&mut op_rng, input_len),
                            }
                        };
                        let result = client.call(&req);
                        // Open-loop latency: from scheduled arrival.
                        hist.record(due.elapsed());
                        match Outcome::of(&result) {
                            Outcome::Ok => counters.ok.fetch_add(1, Ordering::Relaxed),
                            Outcome::Overloaded => {
                                counters.overloaded.fetch_add(1, Ordering::Relaxed)
                            }
                            Outcome::AppError => {
                                counters.app_errors.fetch_add(1, Ordering::Relaxed)
                            }
                            Outcome::ProtocolError => {
                                counters.protocol_errors.fetch_add(1, Ordering::Relaxed)
                            }
                        };
                    }
                })
                .context("spawning loadgen worker")?,
        );
    }
    for w in workers {
        match w.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => return Err(e.context("loadgen worker failed")),
            Err(_) => bail!("loadgen worker panicked"),
        }
    }
    let wall = start.elapsed();

    let server = probe.metrics().ok();
    Ok(LoadReport {
        offered_rps: cfg.rps,
        sent: schedule.len() as u64,
        ok: counters.ok.load(Ordering::Relaxed),
        overloaded: counters.overloaded.load(Ordering::Relaxed),
        app_errors: counters.app_errors.load(Ordering::Relaxed),
        protocol_errors: counters.protocol_errors.load(Ordering::Relaxed),
        wall,
        latency: hist.snapshot(),
        server,
    })
}

fn rand_input(rng: &mut Rng, len: usize) -> Vec<u8> {
    (0..len).map(|_| rng.below(16) as u8).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation() {
        let mut cfg = LoadgenConfig { rps: 0.0, ..Default::default() };
        assert!(run(&cfg).is_err());
        cfg.rps = 100.0;
        cfg.learn_frac = 1.5;
        assert!(run(&cfg).is_err());
        cfg.learn_frac = 0.1;
        cfg.sessions = 0;
        assert!(run(&cfg).is_err());
    }

    #[test]
    fn report_formats() {
        let r = LoadReport {
            offered_rps: 100.0,
            sent: 10,
            ok: 9,
            overloaded: 1,
            app_errors: 0,
            protocol_errors: 0,
            wall: Duration::from_secs(1),
            latency: HistSnapshot::default(),
            server: None,
        };
        let s = r.report();
        assert!(s.contains("9 ok"));
        assert!(s.contains("p99"));
        assert!((r.achieved_rps() - 9.0).abs() < 1e-9);
    }
}
