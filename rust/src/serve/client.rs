//! Blocking client for the serve wire protocol, with reconnect + timeout
//! handling and protocol-v3 request pipelining.
//!
//! One submission surface, two layers of convenience:
//!
//! * [`Client::send`] / [`Ticket::wait`] — the core: every operation is a
//!   typed [`Request`] value; `send` writes one tagged frame and returns
//!   immediately with its [`Ticket`]; any number may be in flight on the
//!   one connection, and a wait collects responses in *any* order (the
//!   server tags each response with its request id). This is how a single
//!   connection saturates every shard of the server. Version gating,
//!   pipelining, and retry-safety all live here (and in [`Client::call`])
//!   — nowhere else.
//! * [`Client::call`] and the typed helpers (`classify`, `learn_way`, …)
//!   — the blocking convenience layer (send + wait for one request), with
//!   the original reconnect / retry discipline when nothing else is in
//!   flight. Each helper is a thin wrapper that builds a [`Request`] and
//!   folds server errors into `anyhow` errors.
//!
//! Transport and framing failures are `Err` (after the configured
//! reconnect attempts), while server-sent `Error` frames come back as
//! `Ok(WireResponse::Error { .. })` so callers like the load generator can
//! count `Overloaded` (expected under backpressure) separately from
//! protocol failures (never expected). The typed convenience methods fold
//! server errors into `anyhow` errors for ordinary callers.
//!
//! Set [`ClientConfig::version`] below 3 to speak an older protocol:
//! frames go out untagged and responses are matched in arrival order (the
//! server answers pre-v3 frames strictly in order), which is exactly the
//! v1/v2 behavior — used by the compatibility tests and the sequential
//! baseline of `benches/serve_loopback.rs`.

use std::collections::{HashMap, VecDeque};
use std::io::BufReader;
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::serve::proto::{
    self, BatchItem, ErrorCode, HealthWire, MetricsWire, SessionInfoWire, StatWire, WireDecision,
    WireReply, WireRequest, WireResponse,
};

/// The single typed request surface: every client entry point builds one
/// of these and hands it to [`Client::send`] / [`Client::call`]. This is
/// the wire-level request enum re-exported under its API name.
pub use crate::serve::proto::WireRequest as Request;

/// Handle to one pipelined in-flight request, returned by
/// [`Client::send`]. Collect it with [`Ticket::wait`] (or the deadline-
/// bounded [`Ticket::wait_until`]) in any order relative to other
/// tickets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Ticket {
    id: u64,
}

impl Ticket {
    /// The wire-level request id this ticket tracks.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until this request's response arrives; responses for other
    /// tickets that arrive first are buffered for their own waits.
    pub fn wait(self, client: &mut Client) -> Result<WireResponse> {
        client.wait(self.id)
    }

    /// Deadline-bounded [`Ticket::wait`]: `Ok(None)` means the response
    /// has not arrived yet and the ticket is still in flight.
    pub fn wait_until(
        self,
        client: &mut Client,
        deadline: Instant,
    ) -> Result<Option<WireResponse>> {
        client.wait_until(self.id, deadline)
    }
}

/// Client tuning knobs.
#[derive(Debug, Clone)]
pub struct ClientConfig {
    /// Socket read/write timeout per call.
    pub timeout: Duration,
    /// Transport failures tolerated per call before giving up (each retry
    /// reconnects from scratch). Only applies when no other requests are
    /// pipelined on the connection — a reconnect would lose them.
    pub reconnect_attempts: u32,
    /// Pause between reconnect attempts.
    pub reconnect_backoff: Duration,
    /// Protocol version to speak, clamped to
    /// `proto::MIN_VERSION..=proto::VERSION`. Pre-v3 sessions send
    /// untagged frames and match responses in arrival order.
    pub version: u8,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            timeout: Duration::from_secs(10),
            reconnect_attempts: 2,
            reconnect_backoff: Duration::from_millis(50),
            version: proto::VERSION,
        }
    }
}

/// One live connection: the write half plus a *persistent* buffered
/// reader. The reader must live as long as the connection — a throwaway
/// `BufReader` per response could buffer (and then drop) the next
/// pipelined response behind the one being read.
struct Conn {
    write: TcpStream,
    read: BufReader<TcpStream>,
}

/// Blocking connection to a serve endpoint with optional pipelining.
pub struct Client {
    addr: String,
    cfg: ClientConfig,
    conn: Option<Conn>,
    next_id: u64,
    /// Submitted-but-unwaited request ids, in submit order (the order a
    /// pre-v3 server answers in).
    pending: VecDeque<u64>,
    /// Responses that arrived while waiting for a different id.
    completed: HashMap<u64, WireResponse>,
}

impl Client {
    /// Connect with default configuration.
    pub fn connect(addr: impl Into<String>) -> Result<Client> {
        Client::with_config(addr, ClientConfig::default())
    }

    pub fn with_config(addr: impl Into<String>, cfg: ClientConfig) -> Result<Client> {
        let mut c = Client {
            addr: addr.into(),
            cfg,
            conn: None,
            next_id: 1,
            pending: VecDeque::new(),
            completed: HashMap::new(),
        };
        c.ensure_connected()?;
        Ok(c)
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Protocol version this client speaks.
    pub fn version(&self) -> u8 {
        self.cfg.version.clamp(proto::MIN_VERSION, proto::VERSION)
    }

    /// Requests submitted and not yet waited for.
    pub fn in_flight(&self) -> usize {
        self.pending.len()
    }

    fn ensure_connected(&mut self) -> Result<&mut Conn> {
        if self.conn.is_none() {
            let s = TcpStream::connect(&self.addr)
                .with_context(|| format!("connecting to {}", self.addr))?;
            s.set_read_timeout(Some(self.cfg.timeout))?;
            s.set_write_timeout(Some(self.cfg.timeout))?;
            s.set_nodelay(true).ok();
            let read = BufReader::new(s.try_clone()?);
            self.conn = Some(Conn { write: s, read });
        }
        match &mut self.conn {
            Some(c) => Ok(c),
            None => bail!("connection to {} vanished mid-setup", self.addr),
        }
    }

    /// Drop the connection and every still-pending request (their
    /// responses can never arrive on a new socket). Responses already
    /// received and buffered stay claimable — they were complete before
    /// the failure.
    fn poison(&mut self) {
        self.conn = None;
        self.pending.clear();
    }

    /// Pipelined send: write one tagged request frame and return its
    /// [`Ticket`] without waiting. Any number of sends may be
    /// outstanding; collect them with [`Ticket::wait`] (or
    /// [`Client::wait`]) in any order.
    ///
    /// Unlike [`Client::call`], a transport failure here is not retried:
    /// with other requests possibly in flight, a transparent reconnect
    /// would silently lose them — the error surfaces and poisons the
    /// connection (every outstanding `wait` then fails fast).
    pub fn send(&mut self, req: &Request) -> Result<Ticket> {
        let v = self.version();
        let min = proto::request_min_version(req);
        if min > v {
            // Silently up-versioning the frame would make the server
            // answer it pipelined while this client matches responses in
            // order — responses would cross. Refuse instead.
            bail!("request requires protocol v{min} but this client speaks v{v}");
        }
        let id = self.next_id;
        self.next_id += 1;
        let frame = proto::encode_request_versioned(req, v, id);
        let had_pending = !self.pending.is_empty();
        let wrote = self
            .ensure_connected()
            .and_then(|conn| proto::write_frame(&mut conn.write, &frame));
        match wrote {
            Ok(()) => {
                self.pending.push_back(id);
                Ok(Ticket { id })
            }
            Err(e) => {
                self.poison();
                Err(if had_pending {
                    e.context("transport failed with pipelined requests in flight; all lost")
                } else {
                    e
                })
            }
        }
    }

    /// [`Client::send`] returning the raw request id instead of a
    /// [`Ticket`] — kept for callers that track ids in bulk (the load
    /// generator's in-flight window).
    pub fn submit(&mut self, req: &Request) -> Result<u64> {
        self.send(req).map(|t| t.id())
    }

    /// Collect the response for one submitted ticket, in any order.
    /// Responses for *other* tickets that arrive first are buffered for
    /// their own `wait`. A transport failure poisons the connection and
    /// fails every outstanding ticket.
    pub fn wait(&mut self, id: u64) -> Result<WireResponse> {
        if let Some(resp) = self.completed.remove(&id) {
            return Ok(resp);
        }
        if !self.pending.contains(&id) {
            bail!(
                "request {id} is not in flight (never submitted, lost to a reconnect, \
                 or already waited for)"
            );
        }
        loop {
            let frame = match self.read_response() {
                Ok(f) => f,
                Err(e) => {
                    self.poison();
                    return Err(e.context("reading pipelined response"));
                }
            };
            let (got, resp) = self.admit(frame)?;
            if got == id {
                return Ok(resp);
            }
            self.completed.insert(got, resp);
        }
    }

    /// Deadline-bounded [`Client::wait`]: returns `Ok(None)` — connection
    /// intact, ticket still in flight — if the response has not arrived by
    /// `deadline`. Lets a pipelined caller (the load generator) collect
    /// responses opportunistically during idle gaps without stalling its
    /// own schedule behind a slow request.
    ///
    /// Deadline precision: ~1 ms (the probe read timeout). A frame whose
    /// first bytes arrived but then stalls mid-body can hold the probe up
    /// to `proto::MAX_STALL_RETRIES` x 1 ms (~40 ms) past the deadline —
    /// bounded, and only reachable when the peer stalls inside a frame.
    pub fn wait_until(&mut self, id: u64, deadline: Instant) -> Result<Option<WireResponse>> {
        if let Some(resp) = self.completed.remove(&id) {
            return Ok(Some(resp));
        }
        if !self.pending.contains(&id) {
            bail!(
                "request {id} is not in flight (never submitted, lost to a reconnect, \
                 or already waited for)"
            );
        }
        loop {
            let now = Instant::now();
            if now >= deadline {
                return Ok(None);
            }
            // Short fixed probe so `read_frame`'s internal mid-frame
            // retries (MAX_STALL_RETRIES of them) cannot multiply a large
            // remaining-time window into seconds of overshoot.
            let probe = Duration::from_millis(1);
            let read = {
                let conn = self.conn.as_mut().ok_or_else(|| anyhow!("not connected"))?;
                let _ = conn.read.get_ref().set_read_timeout(Some(probe));
                let r = proto::read_frame(&mut conn.read);
                let _ = conn.read.get_ref().set_read_timeout(Some(self.cfg.timeout));
                r
            };
            let frame = match read {
                Ok(Some(blob)) => match proto::decode_response(&blob) {
                    Ok(f) => f,
                    Err(e) => {
                        self.poison();
                        return Err(e.context("decoding pipelined response"));
                    }
                },
                Ok(None) => {
                    self.poison();
                    return Err(anyhow!("server closed the connection"));
                }
                Err(e) => {
                    if let Some(ioe) = e.downcast_ref::<std::io::Error>() {
                        if matches!(
                            ioe.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        ) {
                            continue; // nothing arrived yet; re-check deadline
                        }
                    }
                    self.poison();
                    return Err(e.context("reading pipelined response"));
                }
            };
            let (got, resp) = self.admit(frame)?;
            if got == id {
                return Ok(Some(resp));
            }
            self.completed.insert(got, resp);
        }
    }

    /// Match one arrived response frame to its ticket — by tag at v3, by
    /// submit order (FIFO) below — removing the ticket from `pending`.
    fn admit(&mut self, frame: proto::ResponseFrame) -> Result<(u64, WireResponse)> {
        let got = if self.version() >= 3 {
            frame.request_id
        } else {
            // Pre-v3 servers answer strictly in submit order.
            self.pending.front().copied().unwrap_or(0)
        };
        match self.pending.iter().position(|&p| p == got) {
            Some(pos) => {
                self.pending.remove(pos);
            }
            None => {
                self.poison();
                bail!("server answered unknown request id {got}");
            }
        }
        Ok((got, frame.resp))
    }

    fn read_response(&mut self) -> Result<proto::ResponseFrame> {
        let conn = self.conn.as_mut().ok_or_else(|| anyhow!("not connected"))?;
        let blob = proto::read_frame(&mut conn.read)?
            .ok_or_else(|| anyhow!("server closed the connection"))?;
        proto::decode_response(&blob)
    }

    /// Raw call: send one request frame, wait for its response frame.
    /// Reconnects and retries on transport errors up to the configured
    /// attempt budget; server `Error` frames are returned as `Ok`.
    ///
    /// Retry discipline: a failure *before* the request hit the wire is
    /// always retried. A failure *after* it may have been sent is only
    /// retried for idempotent requests — re-sending a `LearnWay` or
    /// `AddShots` whose reply was lost could apply the learning twice,
    /// re-sending a `StreamPush` would advance the stream twice, and
    /// re-sending a `SessionImport` could clobber writes that landed on
    /// the restored session between the two deliveries, so those surface
    /// as errors for the caller to decide. With pipelined requests
    /// already in flight there is no retry at all (a reconnect would
    /// lose them).
    pub fn call(&mut self, req: &WireRequest) -> Result<WireResponse> {
        let v = self.version();
        let min = proto::request_min_version(req);
        if min > v {
            // Permanent condition: fail before the retry loop can tear
            // down a healthy connection over it.
            bail!("request requires protocol v{min} but this client speaks v{v}");
        }
        if !self.pending.is_empty() {
            let id = self.submit(req)?;
            return self.wait(id);
        }
        let idempotent = !matches!(
            req,
            WireRequest::LearnWay { .. }
                | WireRequest::AddShots { .. }
                | WireRequest::StreamPush { .. }
                | WireRequest::SessionImport { .. }
        );
        let mut last_err: Option<anyhow::Error> = None;
        for attempt in 0..=self.cfg.reconnect_attempts {
            if attempt > 0 {
                std::thread::sleep(self.cfg.reconnect_backoff);
            }
            match self.try_call(req) {
                Ok(resp) => return Ok(resp),
                Err(CallError::NotSent(e)) => {
                    self.poison();
                    last_err = Some(e);
                }
                Err(CallError::Sent(e)) => {
                    // Drop the (possibly poisoned) connection before retry.
                    self.poison();
                    if !idempotent {
                        return Err(e.context(
                            "transport failed after a non-idempotent request may have \
                             been sent; not retrying (the server may have applied it)",
                        ));
                    }
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.unwrap_or_else(|| anyhow!("call failed with no attempts")))
    }

    fn try_call(&mut self, req: &WireRequest) -> std::result::Result<WireResponse, CallError> {
        let v = self.version();
        let id = self.next_id;
        self.next_id += 1;
        let frame = proto::encode_request_versioned(req, v, id);
        let conn = self.ensure_connected().map_err(CallError::NotSent)?;
        proto::write_frame(&mut conn.write, &frame).map_err(CallError::Sent)?;
        let blob = proto::read_frame(&mut conn.read)
            .map_err(CallError::Sent)?
            .ok_or_else(|| CallError::Sent(anyhow!("server closed the connection")))?;
        let rf = proto::decode_response(&blob).map_err(CallError::Sent)?;
        if v >= 3 && rf.request_id != id {
            return Err(CallError::Sent(anyhow!(
                "response tag {} does not match request {id}",
                rf.request_id
            )));
        }
        Ok(rf.resp)
    }

    /// Blocking call with the response narrowed to one expected variant:
    /// server `Error` frames fold into `anyhow` errors (exactly as the
    /// typed helpers always have), any other unexpected variant is handed
    /// back to `pick` and reported verbatim. Every typed helper below is
    /// a one-line wrapper over this.
    fn demand<T>(
        &mut self,
        req: &Request,
        pick: fn(WireResponse) -> std::result::Result<T, WireResponse>,
    ) -> Result<T> {
        match self.call(req)? {
            WireResponse::Error { code, message } => {
                bail!("server error ({code:?}): {message}")
            }
            other => match pick(other) {
                Ok(v) => Ok(v),
                Err(other) => bail!("unexpected response {other:?}"),
            },
        }
    }

    fn expect_reply(&mut self, req: &Request) -> Result<WireReply> {
        self.demand(req, |r| match r {
            WireResponse::Reply(rep) => Ok(rep),
            other => Err(other),
        })
    }

    /// Classify with the model's built-in head.
    pub fn classify(&mut self, input: Vec<u8>) -> Result<WireReply> {
        self.expect_reply(&Request::Classify { input })
    }

    /// Classify a batch of session-less windows in one frame (v3); items
    /// come back in input order, each independently a reply or an error.
    pub fn classify_batch(&mut self, inputs: Vec<Vec<u8>>) -> Result<Vec<BatchItem>> {
        self.demand(&Request::ClassifyBatch { inputs }, |r| match r {
            WireResponse::ReplyBatch(items) => Ok(items),
            other => Err(other),
        })
    }

    /// Classify against a session's learned head.
    pub fn classify_session(&mut self, session: u64, input: Vec<u8>) -> Result<WireReply> {
        self.expect_reply(&Request::ClassifySession { session, input })
    }

    /// Learn one new way for a session.
    pub fn learn_way(&mut self, session: u64, shots: Vec<Vec<u8>>) -> Result<WireReply> {
        self.expect_reply(&Request::LearnWay { session, shots })
    }

    /// Fold new support shots into an already-learned way of a session
    /// (v4, continual learning). The reply's `learned_way` echoes the
    /// updated way. Not retried after a transport failure mid-call — a
    /// lost reply could mean the shots were already absorbed.
    pub fn add_shots(&mut self, session: u64, way: u64, shots: Vec<Vec<u8>>) -> Result<WireReply> {
        self.expect_reply(&Request::AddShots { session, way, shots })
    }

    /// A session's learned state + way-budget accounting (v4).
    pub fn session_info(&mut self, session: u64) -> Result<SessionInfoWire> {
        self.demand(&Request::SessionInfo { session }, |r| match r {
            WireResponse::SessionInfo(si) => Ok(si),
            other => Err(other),
        })
    }

    /// Evict a session; returns whether it existed.
    pub fn evict_session(&mut self, session: u64) -> Result<bool> {
        self.demand(&Request::EvictSession { session }, |r| match r {
            WireResponse::Evicted { existed } => Ok(existed),
            other => Err(other),
        })
    }

    /// Open (or reset) an incremental stream on a session; returns the
    /// accepted `(window, hop)` geometry in timesteps.
    pub fn stream_open(&mut self, session: u64, hop: u32) -> Result<(u32, u32)> {
        self.demand(&Request::StreamOpen { session, hop }, |r| match r {
            WireResponse::StreamOpened { window, hop } => Ok((window, hop)),
            other => Err(other),
        })
    }

    /// Push a chunk of u4 samples into a session's open stream; returns a
    /// decision for every window the chunk completed (often empty).
    pub fn stream_push(&mut self, session: u64, samples: Vec<u8>) -> Result<Vec<WireDecision>> {
        self.demand(&Request::StreamPush { session, samples }, |r| match r {
            WireResponse::StreamDecisions(ds) => Ok(ds),
            other => Err(other),
        })
    }

    /// Close a session's stream; returns whether one existed and how many
    /// windows it emitted.
    pub fn stream_close(&mut self, session: u64) -> Result<(bool, u64)> {
        self.demand(&Request::StreamClose { session }, |r| match r {
            WireResponse::StreamClosed { existed, windows } => Ok((existed, windows)),
            other => Err(other),
        })
    }

    /// Export a session's full learner state as an opaque snapshot blob
    /// (v6, durability). A pure read: the session's LRU recency is left
    /// untouched, so walking every session for a snapshot does not evict
    /// anything. Fails locally with a version error on older protocols.
    pub fn session_export(&mut self, session: u64) -> Result<Vec<u8>> {
        self.demand(&Request::SessionExport { session }, |r| match r {
            WireResponse::SessionExported { blob } => Ok(blob),
            other => Err(other),
        })
    }

    /// Replace (or create) a session's learner state from a snapshot blob
    /// previously produced by [`Client::session_export`] (v6). The reply
    /// is the imported session's info — accounting as re-bounded by
    /// *this* server's way budget. Not retried after a transport failure
    /// mid-call: a re-sent import could clobber writes that landed on the
    /// restored session in between.
    pub fn session_import(&mut self, session: u64, blob: Vec<u8>) -> Result<SessionInfoWire> {
        self.demand(&Request::SessionImport { session, blob }, |r| match r {
            WireResponse::SessionInfo(si) => Ok(si),
            other => Err(other),
        })
    }

    /// Liveness + model geometry probe.
    pub fn health(&mut self) -> Result<HealthWire> {
        self.demand(&Request::Health, |r| match r {
            WireResponse::Health(h) => Ok(h),
            other => Err(other),
        })
    }

    /// Aggregated serving metrics across all shards.
    pub fn metrics(&mut self) -> Result<MetricsWire> {
        self.demand(&Request::Metrics, |r| match r {
            WireResponse::Metrics(m) => Ok(m),
            other => Err(other),
        })
    }

    /// Flight-recorder dump merged across all shards (v5). Fails locally
    /// with a version error when this client speaks an older protocol.
    pub fn stat(&mut self) -> Result<StatWire> {
        self.demand(&Request::Stat, |r| match r {
            WireResponse::Stat(st) => Ok(st),
            other => Err(other),
        })
    }
}

/// Whether a transport failure happened before or after the request may
/// have reached the server — decides retry safety for non-idempotent ops.
enum CallError {
    NotSent(anyhow::Error),
    Sent(anyhow::Error),
}

/// Classify the outcome of a raw [`Client::call`] for load accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// A successful operation reply.
    Ok,
    /// Backpressure shed by the server — expected under overload.
    Overloaded,
    /// Well-formed but failed at the application layer.
    AppError,
    /// Transport or framing failure — never expected against a healthy
    /// loopback server.
    ProtocolError,
}

impl Outcome {
    pub fn of(result: &Result<WireResponse>) -> Outcome {
        match result {
            Ok(WireResponse::Error { code: ErrorCode::Overloaded, .. }) => Outcome::Overloaded,
            Ok(WireResponse::Error { code: ErrorCode::Malformed, .. }) => Outcome::ProtocolError,
            Ok(WireResponse::Error { .. }) => Outcome::AppError,
            Ok(_) => Outcome::Ok,
            Err(_) => Outcome::ProtocolError,
        }
    }
}
