//! Blocking client for the serve wire protocol, with reconnect + timeout
//! handling.
//!
//! [`Client::call`] is the raw request/response primitive: transport and
//! framing failures are `Err` (after the configured reconnect attempts),
//! while server-sent `Error` frames come back as
//! `Ok(WireResponse::Error { .. })` so callers like the load generator can
//! count `Overloaded` (expected under backpressure) separately from
//! protocol failures (never expected). The typed convenience methods fold
//! server errors into `anyhow` errors for ordinary callers.

use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::serve::proto::{
    self, ErrorCode, HealthWire, MetricsWire, WireDecision, WireReply, WireRequest, WireResponse,
};

/// Client tuning knobs.
#[derive(Debug, Clone)]
pub struct ClientConfig {
    /// Socket read/write timeout per call.
    pub timeout: Duration,
    /// Transport failures tolerated per call before giving up (each retry
    /// reconnects from scratch).
    pub reconnect_attempts: u32,
    /// Pause between reconnect attempts.
    pub reconnect_backoff: Duration,
}

impl Default for ClientConfig {
    fn default() -> Self {
        ClientConfig {
            timeout: Duration::from_secs(10),
            reconnect_attempts: 2,
            reconnect_backoff: Duration::from_millis(50),
        }
    }
}

/// Blocking connection to a serve endpoint. One in-flight request at a
/// time (the protocol is strictly request/response per connection); use
/// one client per thread to pipeline.
pub struct Client {
    addr: String,
    cfg: ClientConfig,
    stream: Option<TcpStream>,
}

impl Client {
    /// Connect with default configuration.
    pub fn connect(addr: impl Into<String>) -> Result<Client> {
        Client::with_config(addr, ClientConfig::default())
    }

    pub fn with_config(addr: impl Into<String>, cfg: ClientConfig) -> Result<Client> {
        let mut c = Client { addr: addr.into(), cfg, stream: None };
        c.ensure_connected()?;
        Ok(c)
    }

    pub fn addr(&self) -> &str {
        &self.addr
    }

    fn ensure_connected(&mut self) -> Result<&mut TcpStream> {
        if self.stream.is_none() {
            let s = TcpStream::connect(&self.addr)
                .with_context(|| format!("connecting to {}", self.addr))?;
            s.set_read_timeout(Some(self.cfg.timeout))?;
            s.set_write_timeout(Some(self.cfg.timeout))?;
            s.set_nodelay(true).ok();
            self.stream = Some(s);
        }
        Ok(self.stream.as_mut().unwrap())
    }

    /// Raw call: send one request frame, read one response frame.
    /// Reconnects and retries on transport errors up to the configured
    /// attempt budget; server `Error` frames are returned as `Ok`.
    ///
    /// Retry discipline: a failure *before* the request hit the wire is
    /// always retried. A failure *after* it may have been sent is only
    /// retried for idempotent requests — re-sending a `LearnWay` whose
    /// reply was lost could apply the learning twice, and re-sending a
    /// `StreamPush` would advance the stream twice, so those surface as
    /// errors for the caller to decide.
    pub fn call(&mut self, req: &WireRequest) -> Result<WireResponse> {
        let frame = proto::encode_request(req);
        let idempotent =
            !matches!(req, WireRequest::LearnWay { .. } | WireRequest::StreamPush { .. });
        let mut last_err: Option<anyhow::Error> = None;
        for attempt in 0..=self.cfg.reconnect_attempts {
            if attempt > 0 {
                std::thread::sleep(self.cfg.reconnect_backoff);
            }
            match self.try_call(&frame) {
                Ok(resp) => return Ok(resp),
                Err(CallError::NotSent(e)) => {
                    self.stream = None;
                    last_err = Some(e);
                }
                Err(CallError::Sent(e)) => {
                    // Drop the (possibly poisoned) connection before retry.
                    self.stream = None;
                    if !idempotent {
                        return Err(e.context(
                            "transport failed after a non-idempotent request may have \
                             been sent; not retrying (the server may have applied it)",
                        ));
                    }
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.unwrap_or_else(|| anyhow!("call failed with no attempts")))
    }

    fn try_call(&mut self, frame: &[u8]) -> std::result::Result<WireResponse, CallError> {
        let stream = self.ensure_connected().map_err(CallError::NotSent)?;
        let cloned = stream.try_clone().map_err(|e| CallError::NotSent(e.into()))?;
        let mut writer = BufWriter::new(cloned);
        proto::write_frame(&mut writer, frame).map_err(CallError::Sent)?;
        drop(writer);
        let reader_stream = self
            .stream
            .as_mut()
            .unwrap()
            .try_clone()
            .map_err(|e| CallError::Sent(e.into()))?;
        let mut reader = BufReader::new(reader_stream);
        let blob = proto::read_frame(&mut reader)
            .map_err(CallError::Sent)?
            .ok_or_else(|| CallError::Sent(anyhow!("server closed the connection")))?;
        proto::decode_response(&blob).map_err(CallError::Sent)
    }

    fn expect_reply(&mut self, req: &WireRequest) -> Result<WireReply> {
        match self.call(req)? {
            WireResponse::Reply(r) => Ok(r),
            WireResponse::Error { code, message } => {
                bail!("server error ({code:?}): {message}")
            }
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Classify with the model's built-in head.
    pub fn classify(&mut self, input: Vec<u8>) -> Result<WireReply> {
        self.expect_reply(&WireRequest::Classify { input })
    }

    /// Classify against a session's learned head.
    pub fn classify_session(&mut self, session: u64, input: Vec<u8>) -> Result<WireReply> {
        self.expect_reply(&WireRequest::ClassifySession { session, input })
    }

    /// Learn one new way for a session.
    pub fn learn_way(&mut self, session: u64, shots: Vec<Vec<u8>>) -> Result<WireReply> {
        self.expect_reply(&WireRequest::LearnWay { session, shots })
    }

    /// Evict a session; returns whether it existed.
    pub fn evict_session(&mut self, session: u64) -> Result<bool> {
        match self.call(&WireRequest::EvictSession { session })? {
            WireResponse::Evicted { existed } => Ok(existed),
            WireResponse::Error { code, message } => {
                bail!("server error ({code:?}): {message}")
            }
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Open (or reset) an incremental stream on a session; returns the
    /// accepted `(window, hop)` geometry in timesteps.
    pub fn stream_open(&mut self, session: u64, hop: u32) -> Result<(u32, u32)> {
        match self.call(&WireRequest::StreamOpen { session, hop })? {
            WireResponse::StreamOpened { window, hop } => Ok((window, hop)),
            WireResponse::Error { code, message } => {
                bail!("server error ({code:?}): {message}")
            }
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Push a chunk of u4 samples into a session's open stream; returns a
    /// decision for every window the chunk completed (often empty).
    pub fn stream_push(&mut self, session: u64, samples: Vec<u8>) -> Result<Vec<WireDecision>> {
        match self.call(&WireRequest::StreamPush { session, samples })? {
            WireResponse::StreamDecisions(ds) => Ok(ds),
            WireResponse::Error { code, message } => {
                bail!("server error ({code:?}): {message}")
            }
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Close a session's stream; returns whether one existed and how many
    /// windows it emitted.
    pub fn stream_close(&mut self, session: u64) -> Result<(bool, u64)> {
        match self.call(&WireRequest::StreamClose { session })? {
            WireResponse::StreamClosed { existed, windows } => Ok((existed, windows)),
            WireResponse::Error { code, message } => {
                bail!("server error ({code:?}): {message}")
            }
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Liveness + model geometry probe.
    pub fn health(&mut self) -> Result<HealthWire> {
        match self.call(&WireRequest::Health)? {
            WireResponse::Health(h) => Ok(h),
            WireResponse::Error { code, message } => {
                bail!("server error ({code:?}): {message}")
            }
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Aggregated serving metrics across all shards.
    pub fn metrics(&mut self) -> Result<MetricsWire> {
        match self.call(&WireRequest::Metrics)? {
            WireResponse::Metrics(m) => Ok(m),
            WireResponse::Error { code, message } => {
                bail!("server error ({code:?}): {message}")
            }
            other => bail!("unexpected response {other:?}"),
        }
    }
}

/// Whether a transport failure happened before or after the request may
/// have reached the server — decides retry safety for non-idempotent ops.
enum CallError {
    NotSent(anyhow::Error),
    Sent(anyhow::Error),
}

/// Classify the outcome of a raw [`Client::call`] for load accounting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Outcome {
    /// A successful operation reply.
    Ok,
    /// Backpressure shed by the server — expected under overload.
    Overloaded,
    /// Well-formed but failed at the application layer.
    AppError,
    /// Transport or framing failure — never expected against a healthy
    /// loopback server.
    ProtocolError,
}

impl Outcome {
    pub fn of(result: &Result<WireResponse>) -> Outcome {
        match result {
            Ok(WireResponse::Error { code: ErrorCode::Overloaded, .. }) => Outcome::Overloaded,
            Ok(WireResponse::Error { code: ErrorCode::Malformed, .. }) => Outcome::ProtocolError,
            Ok(WireResponse::Error { .. }) => Outcome::AppError,
            Ok(_) => Outcome::Ok,
            Err(_) => Outcome::ProtocolError,
        }
    }
}
