//! TCP server fronting N sharded [`Coordinator`]s, with per-connection
//! request pipelining (protocol v3) and two interchangeable transport
//! [`Backend`]s behind one [`ServeConfig`] (DESIGN.md §Serve core):
//!
//! * [`Backend::Reactor`] (default on Linux x86_64/aarch64) — a small
//!   set of epoll event loops owning every connection nonblockingly;
//!   see `serve::reactor` for the event flow.
//! * [`Backend::Threads`] (portable fallback, `CHAMELEON_SERVE_BACKEND=
//!   threads` to force) — the original thread-per-connection model,
//!   implemented in this module.
//!
//! Thread-backend connection anatomy: the connection thread is the
//! **reader** — it decodes frames and dispatches them; a dedicated
//! **writer** thread owns the write side behind an mpsc channel. A v3
//! request is submitted to its shard with a [`ReplySink`] that encodes
//! the response (tagged with the request's id) and enqueues it on the
//! writer *from the worker thread that finished it* — so one connection
//! can keep many requests in flight and responses return in completion
//! order, possibly out of order. Pre-v3 frames are resolved one at a
//! time in arrival order, preserving the strict request/response
//! discipline those clients expect. Both backends share the dispatch,
//! routing, metrics and backpressure semantics below — the serve_e2e
//! suites are the oracle that keeps them bit-for-bit interchangeable.
//!
//! Sharding: session-scoped requests (`ClassifySession`, `LearnWay`,
//! `EvictSession`, stream ops) route by a stable hash of the `SessionId`
//! ([`shard_of`]), so the same session always lands on the same shard no
//! matter which connection carries it — learning stays serialized per
//! session while sessions spread across shards. Session-less `Classify`
//! requests fan out round-robin over all shards, and `ClassifyBatch`
//! spreads its windows the same way, one submission per window.
//!
//! Backpressure: the coordinator's bounded queue is *never* awaited on the
//! accept path — a full queue surfaces as an explicit `Overloaded` wire
//! error instead of a hang. A session-less classify first **fans over**
//! the remaining shards (a single full shard is not cluster overload);
//! only when every shard rejects does the client see `Overloaded`.

use std::fmt;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::num::NonZeroUsize;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::flight::DEFAULT_FLIGHT_CAPACITY;
use crate::coordinator::metrics::{Metrics, MetricsSnapshot, OpKind};
use crate::coordinator::server::{
    Coordinator, CoordinatorConfig, EngineFactory, ReplySink, Request, SubmitError,
};
use crate::coordinator::OpMode;
use crate::serve::proto::{
    self, BatchItem, ErrorCode, FlightEventWire, HealthWire, MetricsWire, StatWire, WireDecision,
    WireReply, WireRequest, WireResponse,
};
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
use crate::serve::reactor;

/// Transport backend behind the serve layer's TCP listener.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Epoll readiness loops (Linux x86_64/aarch64 only): a small set of
    /// event loops own every connection nonblockingly — thousands of
    /// low-duty-cycle connections per node. See `serve::reactor`.
    Reactor,
    /// Thread-per-connection fallback (reader + writer thread per
    /// socket). Portable everywhere; identical wire semantics.
    Threads,
}

impl Backend {
    pub fn name(&self) -> &'static str {
        match self {
            Backend::Reactor => "reactor",
            Backend::Threads => "threads",
        }
    }

    /// Whether the epoll reactor exists on this build target.
    pub const fn reactor_supported() -> bool {
        cfg!(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))
    }
}

/// Typed validation failure from [`ServeConfigBuilder::build`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ConfigError {
    /// `shards == 0`: there would be no coordinator to route to.
    ZeroShards,
    /// `workers_per_shard == 0`: a shard with no engine replicas.
    ZeroWorkers,
    /// `queue_depth == 0`: every submission would be rejected.
    ZeroQueueDepth,
    /// `max_sessions == 0`: no session could ever be admitted.
    ZeroSessions,
    /// `flight_capacity == 0`: the flight-recorder ring needs a slot.
    ZeroFlightCapacity,
    /// Empty bind address.
    EmptyAddr,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            ConfigError::ZeroShards => "shards must be >= 1",
            ConfigError::ZeroWorkers => "workers_per_shard must be >= 1",
            ConfigError::ZeroQueueDepth => "queue_depth must be >= 1",
            ConfigError::ZeroSessions => "max_sessions must be >= 1",
            ConfigError::ZeroFlightCapacity => "flight_capacity must be >= 1",
            ConfigError::EmptyAddr => "bind address must not be empty",
        })
    }
}

impl std::error::Error for ConfigError {}

/// Serving configuration — the one config surface for the serve layer.
///
/// Prefer [`ServeConfig::builder`], which validates into a typed
/// [`ConfigError`]; the fields stay public (with `..Default::default()`
/// struct literals still supported) for embedders that know what they
/// are doing. The per-shard [`CoordinatorConfig`] is derived from this
/// via [`ServeConfig::coordinator_config`] — it is an internal detail of
/// the serve layer, not a second configuration surface.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address; use port 0 for an ephemeral port (tests).
    pub addr: String,
    /// Number of coordinator shards.
    pub shards: usize,
    /// Engine worker threads per shard.
    pub workers_per_shard: usize,
    /// Bounded queue depth per shard (backpressure threshold).
    pub queue_depth: usize,
    /// LRU session cap per shard.
    pub max_sessions: usize,
    /// Per-session prototype-memory budget in bytes (0 = unbounded) — the
    /// continual-learning way cap, enforced per session on its shard.
    pub way_budget_bytes: usize,
    /// Per-connection socket read timeout (thread backend only; the
    /// reactor is readiness-driven and needs no timeout). Thread-backend
    /// connections poll the shutdown flag at this granularity.
    pub read_timeout: Duration,
    /// Service-time threshold (µs) past which a request lands in the
    /// flight recorder as a slow-request event (0 = off).
    pub slow_request_us: u64,
    /// Flight-recorder ring capacity per shard.
    pub flight_capacity: usize,
    /// Operating point the engine replicas should run at (the paper's
    /// dual-mode array as serve configuration). Consumed by the engine
    /// factories the embedder builds — [`Server::start`] itself is
    /// operating-point agnostic.
    pub op_mode: OpMode,
    /// Transport backend. `None` resolves at [`Server::start`]: the
    /// `CHAMELEON_SERVE_BACKEND` env var (`reactor` / `threads`) if set,
    /// else the reactor where supported and threads elsewhere.
    pub backend: Option<Backend>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7070".to_string(),
            shards: 2,
            workers_per_shard: 2,
            queue_depth: 256,
            max_sessions: 1024,
            way_budget_bytes: 0,
            read_timeout: Duration::from_millis(250),
            slow_request_us: 100_000,
            flight_capacity: DEFAULT_FLIGHT_CAPACITY,
            op_mode: OpMode::Paced,
            backend: None,
        }
    }
}

impl ServeConfig {
    /// Start building a validated config from the defaults.
    pub fn builder() -> ServeConfigBuilder {
        ServeConfigBuilder { cfg: ServeConfig::default() }
    }

    /// The per-shard coordinator tuning derived from this config.
    /// Everything under `serve` builds its [`Coordinator`]s from here;
    /// only embedders driving a bare coordinator (no TCP front) should
    /// construct a [`CoordinatorConfig`] by hand.
    pub fn coordinator_config(&self) -> CoordinatorConfig {
        CoordinatorConfig {
            workers: self.workers_per_shard.max(1),
            queue_depth: self.queue_depth.max(1),
            max_sessions: self.max_sessions.max(1),
            way_budget_bytes: self.way_budget_bytes,
            slow_request_us: self.slow_request_us,
            flight_capacity: self.flight_capacity.max(1),
        }
    }

    /// Resolve the transport backend this config will serve with:
    /// explicit [`ServeConfig::backend`] wins, then the
    /// `CHAMELEON_SERVE_BACKEND` env var, then the platform default. A
    /// reactor request on a target without epoll degrades to threads
    /// instead of failing — the two backends are semantically
    /// interchangeable.
    pub fn resolved_backend(&self) -> Backend {
        let requested = self.backend.or_else(|| {
            match std::env::var("CHAMELEON_SERVE_BACKEND").ok().as_deref() {
                Some("reactor") => Some(Backend::Reactor),
                Some("threads") => Some(Backend::Threads),
                _ => None,
            }
        });
        match requested {
            Some(Backend::Threads) => Backend::Threads,
            Some(Backend::Reactor) | None => {
                if Backend::reactor_supported() {
                    Backend::Reactor
                } else {
                    Backend::Threads
                }
            }
        }
    }
}

/// Builder for [`ServeConfig`] (`ServeConfig::builder()`): the validated
/// construction path, collapsing what used to be spread over `ServeConfig`
/// struct literals, `CoordinatorConfig` and CLI flags.
#[derive(Debug, Clone)]
pub struct ServeConfigBuilder {
    cfg: ServeConfig,
}

impl ServeConfigBuilder {
    /// Bind address (port 0 for ephemeral).
    pub fn addr(mut self, addr: impl Into<String>) -> Self {
        self.cfg.addr = addr.into();
        self
    }

    /// Number of coordinator shards (also the reactor's event-loop count).
    pub fn shards(mut self, n: usize) -> Self {
        self.cfg.shards = n;
        self
    }

    /// Engine worker threads per shard.
    pub fn workers_per_shard(mut self, n: usize) -> Self {
        self.cfg.workers_per_shard = n;
        self
    }

    /// Bounded queue depth per shard.
    pub fn queue_depth(mut self, n: usize) -> Self {
        self.cfg.queue_depth = n;
        self
    }

    /// LRU session cap per shard.
    pub fn max_sessions(mut self, n: usize) -> Self {
        self.cfg.max_sessions = n;
        self
    }

    /// Per-session prototype-memory budget in bytes (0 = unbounded).
    pub fn way_budget(mut self, bytes: usize) -> Self {
        self.cfg.way_budget_bytes = bytes;
        self
    }

    /// Thread-backend socket read timeout / shutdown poll granularity.
    pub fn read_timeout(mut self, t: Duration) -> Self {
        self.cfg.read_timeout = t;
        self
    }

    /// Slow-request flight-recorder threshold in µs (0 = off).
    pub fn slow_request_us(mut self, us: u64) -> Self {
        self.cfg.slow_request_us = us;
        self
    }

    /// Flight-recorder ring capacity per shard.
    pub fn flight_capacity(mut self, n: usize) -> Self {
        self.cfg.flight_capacity = n;
        self
    }

    /// Operating point for the engine replicas (paced or turbo).
    pub fn op_mode(mut self, m: OpMode) -> Self {
        self.cfg.op_mode = m;
        self
    }

    /// Pin the transport backend (default: auto-resolve; see
    /// [`ServeConfig::resolved_backend`]).
    pub fn backend(mut self, b: Backend) -> Self {
        self.cfg.backend = Some(b);
        self
    }

    /// Validate and produce the config.
    pub fn build(self) -> std::result::Result<ServeConfig, ConfigError> {
        let c = &self.cfg;
        if c.addr.is_empty() {
            return Err(ConfigError::EmptyAddr);
        }
        if c.shards == 0 {
            return Err(ConfigError::ZeroShards);
        }
        if c.workers_per_shard == 0 {
            return Err(ConfigError::ZeroWorkers);
        }
        if c.queue_depth == 0 {
            return Err(ConfigError::ZeroQueueDepth);
        }
        if c.max_sessions == 0 {
            return Err(ConfigError::ZeroSessions);
        }
        if c.flight_capacity == 0 {
            return Err(ConfigError::ZeroFlightCapacity);
        }
        Ok(self.cfg)
    }
}

/// Stable shard assignment for a session id, checked form (SplitMix64
/// finalizer — the same mix every client/server version computes, so the
/// mapping is part of the protocol contract rather than process state).
/// The shard count is a [`NonZeroUsize`]: the `shards == 0` modulo
/// failure is unrepresentable by type instead of guarded at runtime.
pub fn shard_of_nz(session: u64, shards: NonZeroUsize) -> usize {
    let mut z = session.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z % shards.get() as u64) as usize
}

/// Untyped compatibility wrapper over [`shard_of_nz`]. A `shards` of
/// zero — a caller bug the old signature silently folded into `% 1` — is
/// mapped to shard 0; server-internal routing goes through the checked
/// form and never takes that branch.
pub fn shard_of(session: u64, shards: usize) -> usize {
    NonZeroUsize::new(shards).map_or(0, |n| shard_of_nz(session, n))
}

pub(crate) struct ServerState {
    shards: Vec<Coordinator>,
    /// Worker replicas per shard — sizes `ClassifyBatch` sub-batching so
    /// a batch can occupy every replica, not one per shard.
    workers_per_shard: usize,
    /// Checked shard count (`== shards.len()`): session routing goes
    /// through the typed [`shard_of_nz`] with no runtime guard.
    nshards: NonZeroUsize,
    rr: AtomicUsize,
    pub(crate) stop: AtomicBool,
    pub(crate) live_conns: AtomicU64,
    read_timeout: Duration,
    /// Highest writer backlog (queued-not-yet-written frames) any
    /// connection has reached — behind an `Arc` so every connection's
    /// [`ConnFlow`] can bump it from worker threads (the reactor bumps it
    /// from its event loops). Surfaces in the v5 `Metrics` payload as
    /// `backlog_hwm`.
    pub(crate) backlog_hwm: Arc<AtomicU64>,
}

impl ServerState {
    /// The coordinator shard owning `session` — the one place session ids
    /// meet the shard count, via the checked [`shard_of_nz`].
    fn shard_for(&self, session: u64) -> &Coordinator {
        &self.shards[shard_of_nz(session, self.nshards)]
    }
}

/// The running transport: who owns the listener and the connections.
enum Transport {
    Threads { accept: JoinHandle<()> },
    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    Reactor(reactor::Reactor),
}

/// Running server handle. `shutdown()` (or drop) stops the transport;
/// coordinator workers wind down once the last connection drains.
pub struct Server {
    state: Arc<ServerState>,
    addr: SocketAddr,
    backend: Backend,
    transport: Option<Transport>,
}

impl Server {
    /// Bind and serve. `engines(shard, worker)` yields the engine factory
    /// for each worker replica of each shard.
    pub fn start<F>(cfg: ServeConfig, mut engines: F) -> Result<Server>
    where
        F: FnMut(usize, usize) -> EngineFactory,
    {
        let mut shards = Vec::with_capacity(cfg.shards.max(1));
        // One process-wide flight-recorder epoch shared by every shard:
        // the `Stat` op merges the per-shard rings by timestamp, which is
        // only meaningful when all shards measure from the same zero.
        let epoch = std::time::Instant::now();
        for shard in 0..cfg.shards.max(1) {
            let factories: Vec<EngineFactory> = (0..cfg.workers_per_shard.max(1))
                .map(|worker| engines(shard, worker))
                .collect();
            let coord = Coordinator::start_with_epoch(factories, cfg.coordinator_config(), epoch)
                .with_context(|| format!("starting shard {shard}"))?;
            shards.push(coord);
        }
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let nshards = NonZeroUsize::new(shards.len())
            .ok_or_else(|| anyhow!("config produced zero shards"))?;
        let state = Arc::new(ServerState {
            shards,
            workers_per_shard: cfg.workers_per_shard.max(1),
            nshards,
            rr: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
            live_conns: AtomicU64::new(0),
            read_timeout: cfg.read_timeout,
            backlog_hwm: Arc::new(AtomicU64::new(0)),
        });
        let backend = cfg.resolved_backend();
        let transport = match backend {
            Backend::Threads => Transport::Threads { accept: spawn_accept(listener, &state)? },
            #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
            Backend::Reactor => Transport::Reactor(reactor::Reactor::start(
                listener,
                state.clone(),
                cfg.shards.max(1),
            )?),
            // resolved_backend() never yields Reactor on targets without
            // epoll; keep the arm total anyway so the match is platform
            // independent.
            #[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
            Backend::Reactor => Transport::Threads { accept: spawn_accept(listener, &state)? },
        };
        Ok(Server { state, addr, backend, transport: Some(transport) })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The transport backend this server resolved to (reactor or threads).
    pub fn backend(&self) -> Backend {
        self.backend
    }

    pub fn shard_count(&self) -> usize {
        self.state.shards.len()
    }

    pub fn live_connections(&self) -> u64 {
        self.state.live_conns.load(Ordering::Relaxed)
    }

    /// Aggregated metrics across all shards (merged histograms, plus the
    /// server-level writer-backlog high-water mark).
    pub fn metrics(&self) -> MetricsSnapshot {
        aggregate_full(&self.state)
    }

    /// Merged flight-recorder dump across all shards (the v5 `Stat` op's
    /// payload, also reachable without a connection).
    pub fn stat(&self) -> StatWire {
        stat_dump(&self.state)
    }

    /// Stop the transport. Thread backend: stops accepting, existing
    /// connections drain at their next timeout. Reactor: wakes every
    /// event loop, which closes its connections and exits.
    pub fn shutdown(mut self) {
        self.stop_transport();
    }

    fn stop_transport(&mut self) {
        self.state.stop.store(true, Ordering::SeqCst);
        match self.transport.take() {
            Some(Transport::Threads { accept }) => {
                // Wake the blocking accept with a throwaway connection.
                let _ = TcpStream::connect(self.addr);
                let _ = accept.join();
            }
            #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
            Some(Transport::Reactor(mut r)) => r.shutdown(),
            None => {}
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if self.transport.is_some() {
            self.stop_transport();
        }
    }
}

/// Spawn the thread-backend accept loop.
fn spawn_accept(listener: TcpListener, state: &Arc<ServerState>) -> Result<JoinHandle<()>> {
    let accept_state = state.clone();
    std::thread::Builder::new()
        .name("chameleon-accept".to_string())
        .spawn(move || accept_loop(listener, accept_state))
        .map_err(|e| anyhow!("spawning accept loop: {e}"))
}

fn aggregate(shards: &[Coordinator]) -> MetricsSnapshot {
    let mut it = shards.iter();
    let Some(first) = it.next() else {
        // Config validation rejects shards == 0; an empty slice here can
        // only mean a fresh (all-zero) surface.
        return Metrics::new().snapshot();
    };
    let mut snap = first.snapshot();
    for s in it {
        snap.merge(&s.snapshot());
    }
    snap
}

/// Shard-merged snapshot plus the server-level gauges no coordinator can
/// see (the connection writers' backlog high-water mark).
fn aggregate_full(state: &ServerState) -> MetricsSnapshot {
    let mut snap = aggregate(&state.shards);
    snap.backlog_hwm = snap.backlog_hwm.max(state.backlog_hwm.load(Ordering::Relaxed));
    snap
}

/// Merge every shard's flight-recorder ring into one dump: events ordered
/// by the shards' shared timebase (every shard's recorder is built on one
/// process-wide epoch, so cross-shard `at_us` stamps are comparable),
/// oldest dropped if the merged set would exceed the wire list bound.
/// Since v6 the dump also enumerates every live session id across all
/// shards, sorted — the work-list `chameleon snapshot` exports from.
fn stat_dump(state: &ServerState) -> StatWire {
    let mut recorded = 0u64;
    let mut overwritten = 0u64;
    let mut events: Vec<FlightEventWire> = Vec::new();
    let mut sessions: Vec<u64> = Vec::new();
    for shard in &state.shards {
        let fr = shard.flight_recorder();
        recorded += fr.recorded();
        overwritten += fr.overwritten();
        events.extend(fr.snapshot().iter().map(FlightEventWire::from));
        sessions.extend(shard.session_ids());
    }
    events.sort_by_key(|e| e.at_us);
    if events.len() > proto::MAX_LIST {
        let drop = events.len() - proto::MAX_LIST;
        events.drain(..drop);
    }
    sessions.sort_unstable();
    StatWire { recorded, overwritten, events, sessions }
}

fn accept_loop(listener: TcpListener, state: Arc<ServerState>) {
    for conn in listener.incoming() {
        if state.stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        let conn_state = state.clone();
        let _ = std::thread::Builder::new()
            .name("chameleon-conn".to_string())
            .spawn(move || {
                conn_state.live_conns.fetch_add(1, Ordering::Relaxed);
                let _ = serve_connection(stream, &conn_state);
                conn_state.live_conns.fetch_sub(1, Ordering::Relaxed);
            });
    }
}

/// Responses enqueued on a connection's writer but not yet written before
/// the reader stops accepting new requests. Restores the TCP backpressure
/// the pre-pipelining inline-write design had: a peer that floods
/// requests without reading its responses parks the reader at this bound
/// (thread backend) or drops out of the read-interest set (reactor)
/// instead of growing the response queue without limit. Public so tests
/// and capacity planning can reference the exact bound.
pub const MAX_CONN_BACKLOG: usize = 1024;

/// Shared reader/writer accounting for one connection's response queue.
struct ConnFlow {
    /// Frames enqueued on the writer channel and not yet written out.
    outstanding: AtomicUsize,
    /// Set when the writer thread exits (peer gone); unparks the reader.
    writer_gone: AtomicBool,
    /// The server-wide backlog high-water mark (shared clone of
    /// `ServerState::backlog_hwm`), bumped on every enqueue.
    hwm: Arc<AtomicU64>,
}

/// Enqueue one encoded frame, keeping the backlog count exact even when
/// the writer is already gone.
fn queue_frame(wtx: &mpsc::Sender<Vec<u8>>, flow: &ConnFlow, frame: Vec<u8>) {
    let backlog = flow.outstanding.fetch_add(1, Ordering::AcqRel) + 1;
    flow.hwm.fetch_max(backlog as u64, Ordering::Relaxed);
    if wtx.send(frame).is_err() {
        flow.outstanding.fetch_sub(1, Ordering::AcqRel);
    }
}

/// One connection: the calling thread reads + dispatches frames until EOF,
/// protocol violation, or server shutdown; a paired writer thread drains
/// the response channel so out-of-order completions from pipelined (v3)
/// requests serialize onto the socket without blocking any worker.
fn serve_connection(stream: TcpStream, state: &ServerState) -> Result<()> {
    stream.set_read_timeout(Some(state.read_timeout))?;
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let (wtx, wrx) = mpsc::channel::<Vec<u8>>();
    let flow = Arc::new(ConnFlow {
        outstanding: AtomicUsize::new(0),
        writer_gone: AtomicBool::new(false),
        hwm: state.backlog_hwm.clone(),
    });
    let writer_stream = stream.try_clone()?;
    let writer_flow = flow.clone();
    let writer = std::thread::Builder::new()
        .name("chameleon-conn-writer".to_string())
        .spawn(move || writer_loop(writer_stream, wrx, writer_flow))
        .map_err(|e| anyhow!("spawning connection writer: {e}"))?;
    let result = read_loop(&mut reader, &wtx, &flow, state);
    // Dropping our sender lets the writer exit once every in-flight
    // request has delivered (their sinks hold the remaining clones).
    drop(wtx);
    let _ = writer.join();
    let _ = stream.shutdown(Shutdown::Both);
    result
}

/// Drain encoded response frames onto the socket. Frames already queued
/// behind the current one are coalesced into a single flush.
fn writer_loop(stream: TcpStream, wrx: mpsc::Receiver<Vec<u8>>, flow: Arc<ConnFlow>) {
    let mut w = BufWriter::new(stream);
    'conn: while let Ok(frame) = wrx.recv() {
        if !write_counted(&mut w, &frame, &flow) {
            break 'conn; // peer gone; in-flight responses are dropped
        }
        while let Ok(more) = wrx.try_recv() {
            if !write_counted(&mut w, &more, &flow) {
                break 'conn;
            }
        }
        if w.flush().is_err() {
            break 'conn;
        }
    }
    flow.writer_gone.store(true, Ordering::Release);
}

fn write_counted(w: &mut BufWriter<TcpStream>, frame: &[u8], flow: &ConnFlow) -> bool {
    let ok = w.write_all(frame).is_ok();
    flow.outstanding.fetch_sub(1, Ordering::AcqRel);
    ok
}

fn read_loop<R: Read>(
    reader: &mut R,
    wtx: &mpsc::Sender<Vec<u8>>,
    flow: &Arc<ConnFlow>,
    state: &ServerState,
) -> Result<()> {
    loop {
        // Response-backlog backpressure: a peer that pipelines requests
        // without reading responses parks here (its sends then stall on
        // TCP flow control) instead of growing the writer queue without
        // bound.
        while flow.outstanding.load(Ordering::Acquire) >= MAX_CONN_BACKLOG {
            if state.stop.load(Ordering::SeqCst) || flow.writer_gone.load(Ordering::Acquire) {
                return Ok(());
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        let blob = match proto::read_frame(reader) {
            Ok(Some(b)) => b,
            Ok(None) => return Ok(()), // client closed cleanly
            Err(e) => {
                if let Some(ioe) = e.downcast_ref::<std::io::Error>() {
                    if matches!(
                        ioe.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) {
                        if state.stop.load(Ordering::SeqCst) {
                            return Ok(()); // drain on shutdown
                        }
                        continue; // idle connection; keep polling
                    }
                    return Ok(()); // client vanished mid-frame
                }
                // Hostile or corrupt length prefix: tell the client, close.
                let resp = WireResponse::Error {
                    code: ErrorCode::Malformed,
                    message: format!("{e:#}"),
                };
                queue_frame(wtx, flow, proto::encode_response(&resp));
                return Ok(());
            }
        };
        // Reply at the requester's protocol version (first body byte) with
        // its tag echoed, so every peer receives frames it can decode.
        let peer_version = blob.first().copied().unwrap_or(proto::VERSION);
        let request_id = proto::peek_request_id(&blob);
        match proto::decode_request(&blob) {
            Ok(frame) if frame.version >= 3 => {
                // v3: pipelined. Dispatch and go straight back to reading;
                // the response frame is queued whenever its worker
                // finishes, tagged so the client can match it.
                let out = responder(wtx.clone(), flow.clone(), frame.version, frame.request_id);
                dispatch_request(frame.req, state, out);
            }
            Ok(frame) => {
                // v1/v2 peers expect strict in-order request/response:
                // resolve each request before reading the next frame.
                let resp = handle_sync(frame.req, state);
                let encoded = proto::encode_response_versioned(&resp, frame.version, 0);
                queue_frame(wtx, flow, encoded);
                if flow.writer_gone.load(Ordering::Acquire) {
                    return Ok(()); // peer vanished
                }
            }
            Err(e) => {
                // Malformed payload: answer then close the connection —
                // framing can no longer be trusted.
                let resp = WireResponse::Error {
                    code: ErrorCode::Malformed,
                    message: format!("{e:#}"),
                };
                queue_frame(
                    wtx,
                    flow,
                    proto::encode_response_versioned(&resp, peer_version, request_id),
                );
                return Ok(());
            }
        }
    }
}

/// Build the one-shot completion callback for a v3 request: encode at the
/// peer's version with its tag and queue on the connection writer.
fn responder(
    wtx: mpsc::Sender<Vec<u8>>,
    flow: Arc<ConnFlow>,
    version: u8,
    request_id: u64,
) -> impl FnOnce(WireResponse) + Send + 'static {
    move |resp: WireResponse| {
        queue_frame(&wtx, &flow, proto::encode_response_versioned(&resp, version, request_id));
    }
}

/// Resolve one pre-v3 request synchronously (strict in-order semantics):
/// run it through the same dispatch machinery and block for the single
/// response.
fn handle_sync(req: WireRequest, state: &ServerState) -> WireResponse {
    let (tx, rx) = mpsc::channel::<WireResponse>();
    dispatch_request(req, state, move |resp| {
        let _ = tx.send(resp);
    });
    rx.recv().unwrap_or_else(|_| WireResponse::Error {
        code: ErrorCode::App,
        message: "worker gone before replying".to_string(),
    })
}

/// Route one request. `out` is invoked exactly once with the response —
/// possibly on this thread (`Health`/`Metrics`/`Stat`, submit failures),
/// possibly on a worker thread (everything that reaches a shard). Both
/// transport backends funnel through here, so routing, fan-over and
/// metrics semantics cannot drift between them.
pub(crate) fn dispatch_request<F>(req: WireRequest, state: &ServerState, out: F)
where
    F: FnOnce(WireResponse) + Send + 'static,
{
    let n = state.shards.len();
    match req {
        WireRequest::Classify { input } => {
            submit_classify(state, input, ReplySink::call(move |res| out(fold_response(res))));
        }
        WireRequest::ClassifySession { session, input } => {
            let reply = ReplySink::call(move |res| out(fold_response(res)));
            let shard = state.shard_for(session);
            submit_or_reject(shard, Request::ClassifySession { session, input, reply });
        }
        WireRequest::LearnWay { session, shots } => {
            let reply = ReplySink::call(move |res| out(fold_response(res)));
            let shard = state.shard_for(session);
            submit_or_reject(shard, Request::LearnWay { session, shots, reply });
        }
        // Continual-learning ops are session-scoped like LearnWay: the
        // same stable hash keeps a session's accumulators on one shard.
        WireRequest::AddShots { session, way, shots } => {
            let reply = ReplySink::call(move |res| out(fold_response(res)));
            // The wire carries the way as u64; on targets where that
            // exceeds usize, a plain cast would silently wrap onto an
            // unrelated (likely existing) way — reject instead.
            match usize::try_from(way) {
                Ok(way) => {
                    let shard = state.shard_for(session);
                    submit_or_reject(shard, Request::AddShots { session, way, shots, reply });
                }
                Err(_) => {
                    let e = anyhow!("way {way} exceeds this host's addressable range");
                    reply.deliver(Err(e));
                }
            }
        }
        WireRequest::SessionInfo { session } => {
            let reply = ReplySink::call(move |res| out(fold_response(res)));
            submit_or_reject(state.shard_for(session), Request::SessionInfo { session, reply });
        }
        WireRequest::EvictSession { session } => {
            let reply = ReplySink::call(move |res| out(fold_response(res)));
            submit_or_reject(state.shard_for(session), Request::EvictSession { session, reply });
        }
        WireRequest::Health => {
            let sessions: u64 = state.shards.iter().map(|c| c.session_count() as u64).sum();
            out(WireResponse::Health(HealthWire {
                shards: n as u32,
                live_sessions: sessions,
                input_len: state.shards[0].input_len() as u32,
                embed_dim: state.shards[0].embed_dim() as u32,
                window: state.shards[0].seq_len() as u32,
                channels: state.shards[0].in_channels() as u32,
            }));
        }
        WireRequest::Metrics => {
            out(WireResponse::Metrics(MetricsWire::from(&aggregate_full(state))));
        }
        WireRequest::Stat => {
            out(WireResponse::Stat(stat_dump(state)));
        }
        // Stream ops are session-scoped: same stable hash routing, so a
        // stream's state lives on exactly one shard no matter which
        // connection pushes into it.
        WireRequest::StreamOpen { session, hop } => {
            let reply = ReplySink::call(move |res| out(fold_response(res)));
            let shard = state.shard_for(session);
            submit_or_reject(shard, Request::StreamOpen { session, hop: hop as usize, reply });
        }
        WireRequest::StreamPush { session, samples } => {
            let reply = ReplySink::call(move |res| out(fold_response(res)));
            let shard = state.shard_for(session);
            submit_or_reject(shard, Request::StreamPush { session, samples, reply });
        }
        WireRequest::StreamClose { session } => {
            let reply = ReplySink::call(move |res| out(fold_response(res)));
            submit_or_reject(state.shard_for(session), Request::StreamClose { session, reply });
        }
        WireRequest::ClassifyBatch { inputs } => dispatch_batch(state, inputs, out),
        // Durability ops (v6) are session-scoped: the same stable hash
        // routes an export and a later import of the same id to the same
        // shard, so migration round-trips observe one consistent store.
        WireRequest::SessionExport { session } => {
            let reply = ReplySink::call(move |res| out(fold_response(res)));
            submit_or_reject(state.shard_for(session), Request::SessionExport { session, reply });
        }
        WireRequest::SessionImport { session, blob } => {
            let reply = ReplySink::call(move |res| out(fold_response(res)));
            submit_or_reject(
                state.shard_for(session),
                Request::SessionImport { session, blob, reply },
            );
        }
    }
}

/// Submit a session-scoped request to its shard; a rejection is delivered
/// straight through the request's own reply sink (as `Overloaded` /
/// shutdown), so `out` still fires exactly once.
fn submit_or_reject(coord: &Coordinator, req: Request) {
    if let Err((e, req)) = coord.try_submit_ret(req) {
        req.into_reply().deliver(Err(anyhow::Error::new(e)));
    }
}

/// Session-less classify: start at the round-robin shard, then **fan over**
/// every other shard before surfacing backpressure — one full shard must
/// not shed traffic the rest of the cluster could absorb.
///
/// Metrics discipline: fan-over *attempts* use the unrecorded enqueue, so
/// one logical request ticks `requests` exactly once (on the shard that
/// accepted it) and `rejected` only when the client actually observes
/// `Overloaded` — healthy fan-over must not read as overload.
fn submit_classify(state: &ServerState, input: Vec<u8>, reply: ReplySink) {
    let n = state.shards.len();
    let first = state.rr.fetch_add(1, Ordering::Relaxed) % n;
    let mut req = Request::Classify { input, reply };
    let mut any_full = false;
    for k in 0..n {
        let shard = &state.shards[(first + k) % n];
        match shard.try_enqueue(req) {
            Ok(()) => {
                shard.record_submission(false);
                return;
            }
            Err((e, r)) => {
                req = r;
                any_full |= e == SubmitError::Full;
            }
        }
    }
    // Every shard rejected: true cluster-wide backpressure (or shutdown).
    state.shards[first].record_submission_as(true, OpKind::Classify);
    let e = if any_full { SubmitError::Full } else { SubmitError::Closed };
    req.into_reply().deliver(Err(anyhow::Error::new(e)));
}

/// Cap on windows per `ClassifyMany` sub-batch: keeps coordinator queue
/// slots roughly proportional to admitted work, so the bounded queues
/// still exert backpressure against huge hostile batches (a 4096-window
/// frame costs ~128 slots, not 1) while preserving the per-sub-batch
/// plan/scratch amortization.
const MAX_MANY_WINDOWS: usize = 32;

/// `ClassifyBatch`: split the windows into round-robin sub-batches —
/// enough to occupy every worker replica (`shards x workers`), and at
/// least one per [`MAX_MANY_WINDOWS`] windows — and classify each
/// sub-batch on a single replica via `Request::ClassifyMany`, so every
/// window in a sub-batch runs on one cached execution plan + scratch
/// arena instead of paying per-window queue traffic. Sub-batches fan over
/// full shards like session-less classifies, outcomes land at their
/// original indices, and one `ReplyBatch` is emitted in input order when
/// the last sub-batch lands. Windows still fail independently — a bad
/// (or even panicking) window yields an error *item* from its replica,
/// never a failed frame. (Batch items do not carry `sim_cycles`; the
/// per-request cycle metrics still aggregate.)
fn dispatch_batch<F>(state: &ServerState, inputs: Vec<Vec<u8>>, out: F)
where
    F: FnOnce(WireResponse) + Send + 'static,
{
    if inputs.is_empty() {
        out(WireResponse::ReplyBatch(Vec::new()));
        return;
    }
    struct BatchAcc<F> {
        slots: Mutex<Vec<Option<BatchItem>>>,
        remaining: AtomicUsize,
        out: Mutex<Option<F>>,
    }
    let n_items = inputs.len();
    let lanes = (state.shards.len() * state.workers_per_shard).max(1);
    let groups = n_items.min(lanes.max(n_items.div_ceil(MAX_MANY_WINDOWS)));
    let acc = Arc::new(BatchAcc {
        slots: Mutex::new((0..n_items).map(|_| None).collect::<Vec<_>>()),
        remaining: AtomicUsize::new(groups),
        out: Mutex::new(Some(out)),
    });
    // Window i joins sub-batch i % groups (interleaved round-robin).
    let mut grouped: Vec<(Vec<usize>, Vec<Vec<u8>>)> =
        (0..groups).map(|_| (Vec::new(), Vec::new())).collect();
    for (i, input) in inputs.into_iter().enumerate() {
        grouped[i % groups].0.push(i);
        grouped[i % groups].1.push(input);
    }
    let first = state.rr.fetch_add(1, Ordering::Relaxed);
    for (g, (idxs, windows)) in grouped.into_iter().enumerate() {
        let acc = acc.clone();
        let reply = ReplySink::call(move |res| {
            let items = fold_many(res, idxs.len());
            {
                let mut slots = acc.slots.lock().unwrap_or_else(|p| p.into_inner());
                for (&i, item) in idxs.iter().zip(items) {
                    slots[i] = Some(item);
                }
            }
            if acc.remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                let items: Vec<BatchItem> = {
                    let mut slots = acc.slots.lock().unwrap_or_else(|p| p.into_inner());
                    slots
                        .iter_mut()
                        .map(|s| {
                            // Every slot is filled once `remaining` hits
                            // zero; a hole means a dropped sub-batch and
                            // becomes a per-item error, not a panic.
                            s.take().unwrap_or_else(|| BatchItem::Error {
                                code: ErrorCode::App,
                                message: "batch slot never filled".to_string(),
                            })
                        })
                        .collect()
                };
                if let Some(out) = acc.out.lock().unwrap_or_else(|p| p.into_inner()).take() {
                    out(WireResponse::ReplyBatch(items));
                }
            }
        });
        submit_many(state, windows, reply, (first + g) % state.shards.len());
    }
}

/// Fold one `ClassifyMany` outcome into exactly `n` batch items (the
/// whole sub-batch shares a failure when the submission itself failed).
fn fold_many(res: Result<crate::coordinator::Response>, n: usize) -> Vec<BatchItem> {
    let err_item = |code: ErrorCode, message: &str| BatchItem::Error {
        code,
        message: message.to_string(),
    };
    match res {
        Ok(resp) => {
            // One sub-batch shares one queue/service/write decomposition:
            // its windows ran back to back on a single worker.
            let queue_us = resp.queue_us;
            let service_us = resp.service_us;
            let write_us = resp.done_at.map(micros_since);
            match resp.many {
                Some(items) if items.len() == n => items
                    .into_iter()
                    .map(|item| match item {
                        Ok(mi) => BatchItem::Reply(WireReply {
                            predicted: Some(mi.predicted as u64),
                            logits: Some(mi.logits),
                            learned_way: None,
                            sim_cycles: None,
                            queue_us,
                            service_us,
                            write_us,
                        }),
                        Err(message) => BatchItem::Error { code: ErrorCode::App, message },
                    })
                    .collect(),
                other => {
                    let msg = format!(
                        "unexpected ClassifyMany reply shape ({} items for {n} windows)",
                        other.map_or(0, |v| v.len())
                    );
                    (0..n).map(|_| err_item(ErrorCode::App, &msg)).collect()
                }
            }
        }
        Err(e) => {
            let (code, message) = match fold_response(Err(e)) {
                WireResponse::Error { code, message } => (code, message),
                other => (ErrorCode::App, format!("unexpected batch reply {other:?}")),
            };
            (0..n).map(|_| err_item(code, &message)).collect()
        }
    }
}

/// Submit one `ClassifyMany` sub-batch with classify-style fan-over: try
/// every shard starting at `first` before surfacing backpressure, with
/// the same one-tick-per-logical-request metrics discipline as
/// [`submit_classify`].
fn submit_many(state: &ServerState, inputs: Vec<Vec<u8>>, reply: ReplySink, first: usize) {
    let n = state.shards.len();
    let mut req = Request::ClassifyMany { inputs, reply };
    let mut any_full = false;
    for k in 0..n {
        let shard = &state.shards[(first + k) % n];
        match shard.try_enqueue(req) {
            Ok(()) => {
                shard.record_submission(false);
                return;
            }
            Err((e, r)) => {
                req = r;
                any_full |= e == SubmitError::Full;
            }
        }
    }
    state.shards[first % n].record_submission_as(true, OpKind::ClassifyMany);
    let e = if any_full { SubmitError::Full } else { SubmitError::Closed };
    req.into_reply().deliver(Err(anyhow::Error::new(e)));
}

/// Microseconds elapsed since a worker-side instant — the reply-path
/// (`write_us`) leg of the v5 span decomposition, measured where the
/// response is folded for the wire (i.e. as it is handed toward the
/// connection writer).
fn micros_since(t: std::time::Instant) -> u64 {
    t.elapsed().as_micros().min(u64::MAX as u128) as u64
}

/// Fold a worker's reply (or a submit failure smuggled through the sink)
/// into the wire response.
fn fold_response(res: Result<crate::coordinator::Response>) -> WireResponse {
    match res {
        Ok(resp) => {
            if let Some(existed) = resp.evicted {
                WireResponse::Evicted { existed }
            } else if let Some(info) = resp.stream {
                WireResponse::StreamOpened { window: info.window as u32, hop: info.hop as u32 }
            } else if let Some(ds) = resp.decisions {
                WireResponse::StreamDecisions(
                    ds.into_iter()
                        .map(|d| WireDecision {
                            window: d.window,
                            end_t: d.end_t,
                            predicted: d.predicted as u64,
                            logits: d.logits,
                        })
                        .collect(),
                )
            } else if let Some((existed, windows)) = resp.stream_closed {
                WireResponse::StreamClosed { existed, windows }
            } else if let Some(blob) = resp.session_export {
                WireResponse::SessionExported { blob }
            } else if let Some(si) = resp.session_info {
                WireResponse::SessionInfo(si.into())
            } else {
                WireResponse::Reply(WireReply {
                    predicted: resp.predicted.map(|p| p as u64),
                    logits: resp.logits,
                    learned_way: resp.learned_way.map(|w| w as u64),
                    sim_cycles: resp.sim_cycles,
                    queue_us: resp.queue_us,
                    service_us: resp.service_us,
                    write_us: resp.done_at.map(micros_since),
                })
            }
        }
        Err(e) => match e.downcast_ref::<SubmitError>() {
            Some(SubmitError::Full) => WireResponse::Error {
                code: ErrorCode::Overloaded,
                message: "shard queue full".to_string(),
            },
            Some(SubmitError::Closed) => WireResponse::Error {
                code: ErrorCode::App,
                message: "shard shut down".to_string(),
            },
            None => WireResponse::Error { code: ErrorCode::App, message: format!("{e:#}") },
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_validates_and_derives_coordinator_config() {
        let cfg = ServeConfig::builder()
            .addr("127.0.0.1:0")
            .shards(3)
            .workers_per_shard(2)
            .queue_depth(64)
            .max_sessions(10)
            .way_budget(1024)
            .read_timeout(Duration::from_millis(50))
            .slow_request_us(5)
            .flight_capacity(32)
            .op_mode(OpMode::Turbo)
            .backend(Backend::Threads)
            .build()
            .expect("valid config");
        assert_eq!(cfg.shards, 3);
        assert_eq!(cfg.op_mode, OpMode::Turbo);
        assert_eq!(cfg.backend, Some(Backend::Threads));
        assert_eq!(cfg.resolved_backend(), Backend::Threads);
        let cc = cfg.coordinator_config();
        assert_eq!(cc.workers, 2);
        assert_eq!(cc.queue_depth, 64);
        assert_eq!(cc.max_sessions, 10);
        assert_eq!(cc.way_budget_bytes, 1024);
        assert_eq!(cc.slow_request_us, 5);
        assert_eq!(cc.flight_capacity, 32);

        let cases = [
            (ServeConfig::builder().shards(0).build(), ConfigError::ZeroShards),
            (ServeConfig::builder().workers_per_shard(0).build(), ConfigError::ZeroWorkers),
            (ServeConfig::builder().queue_depth(0).build(), ConfigError::ZeroQueueDepth),
            (ServeConfig::builder().max_sessions(0).build(), ConfigError::ZeroSessions),
            (ServeConfig::builder().flight_capacity(0).build(), ConfigError::ZeroFlightCapacity),
            (ServeConfig::builder().addr("").build(), ConfigError::EmptyAddr),
        ];
        for (got, want) in cases {
            assert_eq!(got.expect_err("must be rejected"), want);
        }
        // The typed errors carry human-readable wording.
        assert!(ConfigError::ZeroShards.to_string().contains("shards"));
    }

    #[test]
    fn zero_shard_count_maps_to_shard_zero_instead_of_panicking() {
        assert_eq!(shard_of(42, 0), 0);
        let nz = NonZeroUsize::new(4).expect("nonzero");
        for s in 0..64u64 {
            assert_eq!(shard_of(s, 4), shard_of_nz(s, nz));
        }
    }

    #[test]
    fn explicit_backend_survives_resolution() {
        // Forcing threads always sticks; forcing the reactor resolves to
        // the reactor exactly where the build target supports it.
        let threads = ServeConfig { backend: Some(Backend::Threads), ..Default::default() };
        assert_eq!(threads.resolved_backend(), Backend::Threads);
        let reactor = ServeConfig { backend: Some(Backend::Reactor), ..Default::default() };
        let resolved = reactor.resolved_backend();
        if Backend::reactor_supported() {
            assert_eq!(resolved, Backend::Reactor);
        } else {
            assert_eq!(resolved, Backend::Threads);
        }
    }

    #[test]
    fn shard_assignment_is_stable_and_spread() {
        for shards in [1usize, 2, 3, 8] {
            let mut seen = vec![0usize; shards];
            for s in 0..256u64 {
                let a = shard_of(s, shards);
                assert_eq!(a, shard_of(s, shards), "must be deterministic");
                assert!(a < shards);
                seen[a] += 1;
            }
            if shards > 1 {
                assert!(
                    seen.iter().all(|&c| c > 0),
                    "256 sessions must touch every one of {shards} shards: {seen:?}"
                );
            }
        }
    }
}
