//! Thread-per-connection TCP server fronting N sharded [`Coordinator`]s.
//!
//! Sharding: session-scoped requests (`ClassifySession`, `LearnWay`,
//! `EvictSession`) route by a stable hash of the `SessionId`
//! ([`shard_of`]), so the same session always lands on the same shard no
//! matter which connection carries it — learning stays serialized per
//! session while sessions spread across shards. Session-less `Classify`
//! requests fan out round-robin over all shards.
//!
//! Backpressure: the coordinator's bounded queue is *never* awaited on the
//! accept path — a full queue surfaces as an explicit `Overloaded` wire
//! error instead of a hang, so clients (and the load generator) observe
//! overload rather than timeouts.

use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use crate::coordinator::metrics::MetricsSnapshot;
use crate::coordinator::server::{
    Coordinator, CoordinatorConfig, EngineFactory, Request, SubmitError,
};
use crate::serve::proto::{
    self, ErrorCode, HealthWire, MetricsWire, WireDecision, WireReply, WireRequest, WireResponse,
};

/// Serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Bind address; use port 0 for an ephemeral port (tests).
    pub addr: String,
    /// Number of coordinator shards.
    pub shards: usize,
    /// Engine worker threads per shard.
    pub workers_per_shard: usize,
    /// Bounded queue depth per shard (backpressure threshold).
    pub queue_depth: usize,
    /// LRU session cap per shard.
    pub max_sessions: usize,
    /// Per-connection socket read timeout; connections poll the shutdown
    /// flag at this granularity.
    pub read_timeout: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7070".to_string(),
            shards: 2,
            workers_per_shard: 2,
            queue_depth: 256,
            max_sessions: 1024,
            read_timeout: Duration::from_millis(250),
        }
    }
}

/// Stable shard assignment for a session id (SplitMix64 finalizer — the
/// same mix every client/server version computes, so the mapping is part
/// of the protocol contract rather than process state).
pub fn shard_of(session: u64, shards: usize) -> usize {
    let mut z = session.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^= z >> 31;
    (z % shards.max(1) as u64) as usize
}

struct ServerState {
    shards: Vec<Coordinator>,
    rr: AtomicUsize,
    stop: AtomicBool,
    live_conns: AtomicU64,
    read_timeout: Duration,
}

/// Running server handle. `shutdown()` (or drop) stops the accept loop;
/// coordinator workers wind down once the last connection drains.
pub struct Server {
    state: Arc<ServerState>,
    addr: SocketAddr,
    accept_thread: Option<JoinHandle<()>>,
}

impl Server {
    /// Bind and serve. `engines(shard, worker)` yields the engine factory
    /// for each worker replica of each shard.
    pub fn start<F>(cfg: ServeConfig, mut engines: F) -> Result<Server>
    where
        F: FnMut(usize, usize) -> EngineFactory,
    {
        let mut shards = Vec::with_capacity(cfg.shards.max(1));
        for shard in 0..cfg.shards.max(1) {
            let factories: Vec<EngineFactory> = (0..cfg.workers_per_shard.max(1))
                .map(|worker| engines(shard, worker))
                .collect();
            let coord = Coordinator::start(
                factories,
                CoordinatorConfig {
                    workers: cfg.workers_per_shard.max(1),
                    queue_depth: cfg.queue_depth,
                    max_sessions: cfg.max_sessions,
                },
            )
            .with_context(|| format!("starting shard {shard}"))?;
            shards.push(coord);
        }
        let listener = TcpListener::bind(&cfg.addr)
            .with_context(|| format!("binding {}", cfg.addr))?;
        let addr = listener.local_addr()?;
        let state = Arc::new(ServerState {
            shards,
            rr: AtomicUsize::new(0),
            stop: AtomicBool::new(false),
            live_conns: AtomicU64::new(0),
            read_timeout: cfg.read_timeout,
        });
        let accept_state = state.clone();
        let accept_thread = std::thread::Builder::new()
            .name("chameleon-accept".to_string())
            .spawn(move || accept_loop(listener, accept_state))
            .map_err(|e| anyhow!("spawning accept loop: {e}"))?;
        Ok(Server { state, addr, accept_thread: Some(accept_thread) })
    }

    /// The bound address (resolves port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn shard_count(&self) -> usize {
        self.state.shards.len()
    }

    pub fn live_connections(&self) -> u64 {
        self.state.live_conns.load(Ordering::Relaxed)
    }

    /// Aggregated metrics across all shards (merged histograms).
    pub fn metrics(&self) -> MetricsSnapshot {
        aggregate(&self.state.shards)
    }

    /// Stop accepting; existing connections drain at their next timeout.
    pub fn shutdown(mut self) {
        self.stop_accept();
    }

    fn stop_accept(&mut self) {
        self.state.stop.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        if self.accept_thread.is_some() {
            self.stop_accept();
        }
    }
}

fn aggregate(shards: &[Coordinator]) -> MetricsSnapshot {
    let mut it = shards.iter();
    let mut snap = it.next().expect("at least one shard").snapshot();
    for s in it {
        snap.merge(&s.snapshot());
    }
    snap
}

fn accept_loop(listener: TcpListener, state: Arc<ServerState>) {
    for conn in listener.incoming() {
        if state.stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        let conn_state = state.clone();
        let _ = std::thread::Builder::new()
            .name("chameleon-conn".to_string())
            .spawn(move || {
                conn_state.live_conns.fetch_add(1, Ordering::Relaxed);
                let _ = serve_connection(stream, &conn_state);
                conn_state.live_conns.fetch_sub(1, Ordering::Relaxed);
            });
    }
}

/// One connection: sequential request/response frames until EOF, protocol
/// violation, or server shutdown.
fn serve_connection(stream: TcpStream, state: &ServerState) -> Result<()> {
    stream.set_read_timeout(Some(state.read_timeout))?;
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    loop {
        let blob = match proto::read_frame(&mut reader) {
            Ok(Some(b)) => b,
            Ok(None) => return Ok(()), // client closed cleanly
            Err(e) => {
                if let Some(ioe) = e.downcast_ref::<std::io::Error>() {
                    if matches!(
                        ioe.kind(),
                        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                    ) {
                        if state.stop.load(Ordering::SeqCst) {
                            return Ok(()); // drain on shutdown
                        }
                        continue; // idle connection; keep polling
                    }
                    return Ok(()); // client vanished mid-frame
                }
                // Hostile or corrupt length prefix: tell the client, close.
                let resp = WireResponse::Error {
                    code: ErrorCode::Malformed,
                    message: format!("{e:#}"),
                };
                let _ = proto::write_frame(&mut writer, &proto::encode_response(&resp));
                return Ok(());
            }
        };
        // Reply at the requester's protocol version (first body byte), so
        // v1 peers receive frames they can decode.
        let peer_version = blob.first().copied().unwrap_or(proto::VERSION);
        let resp = match proto::decode_request(&blob) {
            Ok(req) => handle_request(req, state),
            Err(e) => {
                // Malformed payload: answer then close the connection —
                // framing can no longer be trusted.
                let resp = WireResponse::Error {
                    code: ErrorCode::Malformed,
                    message: format!("{e:#}"),
                };
                let _ = proto::write_frame(
                    &mut writer,
                    &proto::encode_response_versioned(&resp, peer_version),
                );
                return Ok(());
            }
        };
        proto::write_frame(&mut writer, &proto::encode_response_versioned(&resp, peer_version))?;
    }
}

fn handle_request(req: WireRequest, state: &ServerState) -> WireResponse {
    let n = state.shards.len();
    match req {
        WireRequest::Classify { input } => {
            // Session-less: fan out round-robin across shards.
            let shard = state.rr.fetch_add(1, Ordering::Relaxed) % n;
            let (rtx, rrx) = mpsc::channel();
            dispatch(&state.shards[shard], Request::Classify { input, reply: rtx }, rrx)
        }
        WireRequest::ClassifySession { session, input } => {
            let shard = shard_of(session, n);
            let (rtx, rrx) = mpsc::channel();
            dispatch(
                &state.shards[shard],
                Request::ClassifySession { session, input, reply: rtx },
                rrx,
            )
        }
        WireRequest::LearnWay { session, shots } => {
            let shard = shard_of(session, n);
            let (rtx, rrx) = mpsc::channel();
            dispatch(
                &state.shards[shard],
                Request::LearnWay { session, shots, reply: rtx },
                rrx,
            )
        }
        WireRequest::EvictSession { session } => {
            let shard = shard_of(session, n);
            let (rtx, rrx) = mpsc::channel();
            // `dispatch` folds a Response carrying `evicted` into
            // `WireResponse::Evicted` directly.
            dispatch(
                &state.shards[shard],
                Request::EvictSession { session, reply: rtx },
                rrx,
            )
        }
        WireRequest::Health => {
            let sessions: u64 = state.shards.iter().map(|c| c.session_count() as u64).sum();
            WireResponse::Health(HealthWire {
                shards: n as u32,
                live_sessions: sessions,
                input_len: state.shards[0].input_len() as u32,
                embed_dim: state.shards[0].embed_dim() as u32,
                window: state.shards[0].seq_len() as u32,
                channels: state.shards[0].in_channels() as u32,
            })
        }
        WireRequest::Metrics => {
            WireResponse::Metrics(MetricsWire::from(&aggregate(&state.shards)))
        }
        // Stream ops are session-scoped: same stable hash routing, so a
        // stream's state lives on exactly one shard no matter which
        // connection pushes into it.
        WireRequest::StreamOpen { session, hop } => {
            let shard = shard_of(session, n);
            let (rtx, rrx) = mpsc::channel();
            dispatch(
                &state.shards[shard],
                Request::StreamOpen { session, hop: hop as usize, reply: rtx },
                rrx,
            )
        }
        WireRequest::StreamPush { session, samples } => {
            let shard = shard_of(session, n);
            let (rtx, rrx) = mpsc::channel();
            dispatch(
                &state.shards[shard],
                Request::StreamPush { session, samples, reply: rtx },
                rrx,
            )
        }
        WireRequest::StreamClose { session } => {
            let shard = shard_of(session, n);
            let (rtx, rrx) = mpsc::channel();
            dispatch(
                &state.shards[shard],
                Request::StreamClose { session, reply: rtx },
                rrx,
            )
        }
    }
}

/// Submit to a shard and wait for the worker's reply, translating
/// backpressure and failures into wire errors.
fn dispatch(
    coord: &Coordinator,
    req: Request,
    rrx: mpsc::Receiver<Result<crate::coordinator::Response>>,
) -> WireResponse {
    match coord.try_submit(req) {
        Ok(()) => {}
        Err(SubmitError::Full) => {
            return WireResponse::Error {
                code: ErrorCode::Overloaded,
                message: "shard queue full".to_string(),
            }
        }
        Err(SubmitError::Closed) => {
            return WireResponse::Error {
                code: ErrorCode::App,
                message: "shard shut down".to_string(),
            }
        }
    }
    match rrx.recv() {
        Ok(Ok(resp)) => {
            if let Some(existed) = resp.evicted {
                WireResponse::Evicted { existed }
            } else if let Some(info) = resp.stream {
                WireResponse::StreamOpened { window: info.window as u32, hop: info.hop as u32 }
            } else if let Some(ds) = resp.decisions {
                WireResponse::StreamDecisions(
                    ds.into_iter()
                        .map(|d| WireDecision {
                            window: d.window,
                            end_t: d.end_t,
                            predicted: d.predicted as u64,
                            logits: d.logits,
                        })
                        .collect(),
                )
            } else if let Some((existed, windows)) = resp.stream_closed {
                WireResponse::StreamClosed { existed, windows }
            } else {
                WireResponse::Reply(WireReply {
                    predicted: resp.predicted.map(|p| p as u64),
                    logits: resp.logits,
                    learned_way: resp.learned_way.map(|w| w as u64),
                    sim_cycles: resp.sim_cycles,
                })
            }
        }
        Ok(Err(e)) => WireResponse::Error { code: ErrorCode::App, message: format!("{e:#}") },
        Err(_) => WireResponse::Error {
            code: ErrorCode::App,
            message: "worker gone before replying".to_string(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shard_assignment_is_stable_and_spread() {
        for shards in [1usize, 2, 3, 8] {
            let mut seen = vec![0usize; shards];
            for s in 0..256u64 {
                let a = shard_of(s, shards);
                assert_eq!(a, shard_of(s, shards), "must be deterministic");
                assert!(a < shards);
                seen[a] += 1;
            }
            if shards > 1 {
                assert!(
                    seen.iter().all(|&c| c > 0),
                    "256 sessions must touch every one of {shards} shards: {seen:?}"
                );
            }
        }
    }
}
