//! Network serving layer: a sharded TCP front end over the coordinator.
//!
//! This is the first subsystem that exercises the whole stack — golden
//! model / cycle simulator / (optional) PJRT runtime, behind the
//! coordinator's bounded queues and session store — across a process
//! boundary. Four pieces (see `DESIGN.md` §Serve):
//!
//! * [`proto`]  — length-prefixed, versioned binary wire protocol;
//! * [`server`] — thread-per-connection TCP server over N coordinator
//!   shards: sessions route by stable `SessionId` hash, session-less
//!   classification fans out round-robin, queue overflow surfaces as an
//!   explicit `Overloaded` wire error;
//! * [`client`] — blocking client library with reconnect + timeouts;
//! * [`loadgen`] — open-loop Poisson load generator reporting throughput
//!   and p50/p95/p99 latency from the shared fixed-bucket histogram.
//!
//! Quickstart (no artifacts needed — uses the built-in demo model):
//!
//! ```text
//! cargo run --release -- serve --shards 2 --workers 2
//! cargo run --release -- loadgen --rps 200 --duration 10 --learn-frac 0.05
//! ```

pub mod client;
pub mod loadgen;
pub mod proto;
pub mod server;

pub use client::{Client, ClientConfig, Outcome};
pub use loadgen::{LoadReport, LoadgenConfig};
pub use proto::{
    ErrorCode, HealthWire, MetricsWire, WireReply, WireRequest, WireResponse,
};
pub use server::{shard_of, ServeConfig, Server};
