//! Network serving layer: a sharded TCP front end over the coordinator.
//!
//! This is the first subsystem that exercises the whole stack — golden
//! model / cycle simulator / (optional) PJRT runtime, behind the
//! coordinator's bounded queues and session store — across a process
//! boundary. Four pieces (see `DESIGN.md` §Serve and §Streaming):
//!
//! * [`proto`]  — length-prefixed, versioned binary wire protocol (v2
//!   adds the incremental stream ops);
//! * [`server`] — thread-per-connection TCP server over N coordinator
//!   shards: sessions (and their open streams) route by stable
//!   `SessionId` hash, session-less classification fans out round-robin,
//!   queue overflow surfaces as an explicit `Overloaded` wire error;
//! * [`client`] — blocking client library with reconnect + timeouts;
//! * [`loadgen`] — open-loop load generators: Poisson request traffic and
//!   paced streaming sessions, both reporting p50/p95/p99 latency from
//!   the shared fixed-bucket histogram.
//!
//! Quickstart (no artifacts needed — uses the built-in demo model):
//!
//! ```text
//! cargo run --release -- serve --shards 2 --workers 2
//! cargo run --release -- loadgen --rps 200 --duration 10 --learn-frac 0.05
//! cargo run --release -- loadgen --stream --chunk 8 --hop 4 --duration 10
//! ```

pub mod client;
pub mod loadgen;
pub mod proto;
pub mod server;

pub use client::{Client, ClientConfig, Outcome};
pub use loadgen::{LoadReport, LoadgenConfig, StreamLoadConfig, StreamReport};
pub use proto::{
    ErrorCode, HealthWire, MetricsWire, WireDecision, WireReply, WireRequest, WireResponse,
};
pub use server::{shard_of, ServeConfig, Server};
