//! Network serving layer: a sharded TCP front end over the coordinator.
//!
//! This is the first subsystem that exercises the whole stack — golden
//! model / cycle simulator / (optional) PJRT runtime, behind the
//! coordinator's bounded queues and session store — across a process
//! boundary. Four pieces (see `DESIGN.md` §Serve and §Streaming):
//!
//! * [`proto`]  — length-prefixed, versioned binary wire protocol (v2
//!   adds the incremental stream ops; v3 adds tagged frames for request
//!   pipelining and the `ClassifyBatch` op; v4 adds the continual-
//!   learning ops `AddShots`/`SessionInfo` and way-budget accounting;
//!   v5 adds the observability surface: per-reply span decomposition,
//!   metrics gauges + per-op latency table, and the `Stat`
//!   flight-recorder dump; v6 adds the durability ops
//!   `SessionExport`/`SessionImport` — opaque snapshot blobs that move a
//!   session's full learner state between servers bit-exactly — and the
//!   live-session id list in `Stat`);
//! * [`server`] — TCP server over N coordinator shards with two
//!   transport backends behind one API: an epoll [`reactor`] (default on
//!   Linux) where N event loops own every connection nonblockingly, and
//!   a thread-per-connection fallback with a reader/dispatcher/writer
//!   split. Both pipeline v3 requests (responses return in completion
//!   order): sessions (and their open streams) route by stable
//!   `SessionId` hash, session-less classification fans out round-robin
//!   — trying every shard before surfacing backpressure — and queue
//!   overflow surfaces as an explicit `Overloaded` wire error.
//!   Configuration is one builder: `ServeConfig::builder()` validates
//!   into a [`ServeConfig`]; `CoordinatorConfig` is derived from it;
//! * [`client`] — blocking client library with reconnect + timeouts plus
//!   pipelined `submit`/`wait` primitives;
//! * [`loadgen`] — load generators: open-loop Poisson request traffic
//!   (optionally pipelined and/or batched), paced streaming sessions, and
//!   growing-way continual-learning sessions (`--cl`), all reporting
//!   p50/p95/p99 latency from the shared fixed-bucket histogram.
//!
//! Quickstart (no artifacts needed — uses the built-in demo model):
//!
//! ```text
//! cargo run --release -- serve --shards 2 --workers 2
//! cargo run --release -- loadgen --rps 200 --duration 10 --learn-frac 0.05
//! cargo run --release -- loadgen --rps 2000 --pipeline 32 --batch 16
//! cargo run --release -- loadgen --stream --chunk 8 --hop 4 --duration 10
//! cargo run --release -- loadgen --cl --ways 50 --shots 10 --duration 10
//! ```

pub mod client;
pub mod loadgen;
pub mod proto;
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub mod reactor;
pub mod server;
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub mod sys;

pub use client::{Client, ClientConfig, Outcome, Request, Ticket};
pub use loadgen::{
    ClLoadConfig, ClLoadReport, FanoutConfig, FanoutReport, LoadReport, LoadgenConfig,
    StreamLoadConfig, StreamReport,
};
pub use proto::{
    BatchItem, ErrorCode, FlightEventWire, HealthWire, MetricsWire, OpMetricsWire, RequestFrame,
    ResponseFrame, SessionInfoWire, StatWire, WireDecision, WireReply, WireRequest, WireResponse,
};
pub use server::{
    shard_of, shard_of_nz, Backend, ConfigError, ServeConfig, ServeConfigBuilder, Server,
    MAX_CONN_BACKLOG,
};
