//! Raw Linux syscall shim for the epoll reactor — no `libc` crate (the
//! repo's no-new-deps rule), no FFI: the handful of syscalls the reactor
//! needs (`epoll_create1`, `epoll_ctl`, `epoll_pwait`, `eventfd2`, plus
//! two quality-of-life calls for tests and the high-fanout load
//! generator) are issued with inline assembly and wrapped in `std::os::fd`
//! ownership types.
//!
//! Gated in `serve/mod.rs` to `target_os = "linux"` on x86_64/aarch64 —
//! the two ABIs whose syscall numbers are encoded below. Everywhere else
//! the serve layer falls back to the thread-per-connection backend and
//! this module does not exist.
//!
//! Error convention: the kernel returns `-errno` in the result register;
//! [`check`] folds that into `std::io::Error`, so callers see the same
//! error surface `std::net` produces.

use std::fs::File;
use std::io;
use std::os::fd::{AsRawFd, FromRawFd, OwnedFd, RawFd};

/// Per-arch syscall numbers (from the kernel's `unistd` tables; these are
/// ABI constants, stable forever on a given arch).
#[cfg(target_arch = "x86_64")]
mod nr {
    pub const EPOLL_CREATE1: usize = 291;
    pub const EPOLL_CTL: usize = 233;
    pub const EPOLL_PWAIT: usize = 281;
    pub const EVENTFD2: usize = 290;
    pub const PRLIMIT64: usize = 302;
    pub const SETSOCKOPT: usize = 54;
}
#[cfg(target_arch = "aarch64")]
mod nr {
    pub const EPOLL_CREATE1: usize = 20;
    pub const EPOLL_CTL: usize = 21;
    pub const EPOLL_PWAIT: usize = 22;
    pub const EVENTFD2: usize = 19;
    pub const PRLIMIT64: usize = 261;
    pub const SETSOCKOPT: usize = 208;
}

/// One raw syscall with up to six arguments.
///
/// # Safety
///
/// The caller must uphold the kernel ABI for syscall `n`: every pointer
/// argument must be valid (and sized as the kernel expects) for the whole
/// call, and the argument count/meaning must match the syscall.
#[cfg(target_arch = "x86_64")]
unsafe fn syscall6(n: usize, a1: usize, a2: usize, a3: usize, a4: usize, a5: usize, a6: usize) -> isize {
    let ret: isize;
    // SAFETY: the contract is delegated to the caller (see the function's
    // `# Safety` section); the asm itself only clobbers what the x86_64
    // syscall ABI clobbers (rcx, r11) and lets the compiler assume memory
    // may be read/written, which covers kernel writes into pointer args.
    unsafe {
        core::arch::asm!(
            "syscall",
            inlateout("rax") n as isize => ret,
            in("rdi") a1,
            in("rsi") a2,
            in("rdx") a3,
            in("r10") a4,
            in("r8") a5,
            in("r9") a6,
            out("rcx") _,
            out("r11") _,
            options(nostack),
        );
    }
    ret
}

/// One raw syscall with up to six arguments.
///
/// # Safety
///
/// Same contract as the x86_64 variant: pointer arguments must be valid
/// for the whole call and match what syscall `n` expects.
#[cfg(target_arch = "aarch64")]
unsafe fn syscall6(n: usize, a1: usize, a2: usize, a3: usize, a4: usize, a5: usize, a6: usize) -> isize {
    let ret: isize;
    // SAFETY: contract delegated to the caller; the aarch64 syscall ABI
    // clobbers only x0 (the return register), and the default asm memory
    // model covers kernel writes into pointer arguments.
    unsafe {
        core::arch::asm!(
            "svc 0",
            in("x8") n,
            inlateout("x0") a1 as isize => ret,
            in("x1") a2,
            in("x2") a3,
            in("x3") a4,
            in("x4") a5,
            in("x5") a6,
            options(nostack),
        );
    }
    ret
}

/// Fold a raw syscall return into `io::Result`: negative values are
/// `-errno` (the kernel reserves `-4095..=-1` for errors).
fn check(ret: isize) -> io::Result<usize> {
    if ret < 0 {
        Err(io::Error::from_raw_os_error(-ret as i32))
    } else {
        Ok(ret as usize)
    }
}

// Readiness bits (uapi `epoll_event.events`).
pub const EPOLLIN: u32 = 0x001;
pub const EPOLLOUT: u32 = 0x004;
pub const EPOLLERR: u32 = 0x008;
pub const EPOLLHUP: u32 = 0x010;
/// Peer closed its write half — lets the loop learn about half-closes
/// without a read() round trip.
pub const EPOLLRDHUP: u32 = 0x2000;

const EPOLL_CTL_ADD: usize = 1;
const EPOLL_CTL_DEL: usize = 2;
const EPOLL_CTL_MOD: usize = 3;
const EPOLL_CLOEXEC: usize = 0o2000000;
const EFD_CLOEXEC: usize = 0o2000000;
const EFD_NONBLOCK: usize = 0o4000;

/// The kernel's `struct epoll_event`. Packed on x86_64 only — that arch's
/// uapi declares it `__attribute__((packed))` (12 bytes); everywhere else
/// it has natural alignment (16 bytes). Getting this wrong corrupts every
/// event after the first, so the layout is mirrored per arch.
#[derive(Debug, Clone, Copy)]
#[cfg_attr(target_arch = "x86_64", repr(C, packed))]
#[cfg_attr(not(target_arch = "x86_64"), repr(C))]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

impl EpollEvent {
    pub fn zeroed() -> EpollEvent {
        EpollEvent { events: 0, data: 0 }
    }
}

/// An owned epoll instance. Registration keys (`data`) are caller-chosen
/// u64 tokens, echoed back verbatim in [`Epoll::wait`] events.
pub struct Epoll {
    fd: OwnedFd,
}

impl Epoll {
    pub fn new() -> io::Result<Epoll> {
        // SAFETY: epoll_create1 takes one flag argument and no pointers.
        let fd = check(unsafe { syscall6(nr::EPOLL_CREATE1, EPOLL_CLOEXEC, 0, 0, 0, 0, 0) })?;
        // SAFETY: the fd was just returned by the kernel and is owned by
        // nobody else; OwnedFd takes over closing it.
        Ok(Epoll { fd: unsafe { OwnedFd::from_raw_fd(fd as RawFd) } })
    }

    fn ctl(&self, op: usize, fd: RawFd, events: u32, data: u64) -> io::Result<()> {
        let mut ev = EpollEvent { events, data };
        let evp = if op == EPOLL_CTL_DEL { 0 } else { std::ptr::addr_of_mut!(ev) as usize };
        // SAFETY: `ev` lives across the call (or is not read at all for
        // DEL, where the kernel ignores the pointer); `fd` validity is the
        // kernel's to check — a stale fd comes back as EBADF, not UB.
        check(unsafe {
            syscall6(nr::EPOLL_CTL, self.fd.as_raw_fd() as usize, op, fd as usize, evp, 0, 0)
        })?;
        Ok(())
    }

    /// Register `fd` with the given interest mask and token.
    pub fn add(&self, fd: RawFd, events: u32, data: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, events, data)
    }

    /// Replace `fd`'s interest mask (the token may change too).
    pub fn modify(&self, fd: RawFd, events: u32, data: u64) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, events, data)
    }

    /// Deregister `fd`.
    pub fn del(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Wait for readiness, filling `events` from the front; returns how
    /// many fired. A negative `timeout_ms` blocks indefinitely. EINTR is
    /// folded into `Ok(0)` — the reactor treats both as "re-check state".
    pub fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        if events.is_empty() {
            return Ok(0);
        }
        // SAFETY: `events` is a live, exclusively borrowed buffer whose
        // length bounds maxevents, so the kernel writes only within it;
        // the null sigmask (arg 5) makes the sigsetsize (arg 6) ignored.
        let ret = unsafe {
            syscall6(
                nr::EPOLL_PWAIT,
                self.fd.as_raw_fd() as usize,
                events.as_mut_ptr() as usize,
                events.len(),
                timeout_ms as usize,
                0,
                0,
            )
        };
        match check(ret) {
            Ok(n) => Ok(n),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => Ok(0),
            Err(e) => Err(e),
        }
    }
}

/// A nonblocking eventfd wrapped in a `File`: written (any 8-byte value)
/// to wake an event loop, read to drain the counter. Nonblocking on both
/// sides, so neither a worker posting a completion nor the loop draining
/// it can ever park.
pub fn eventfd() -> io::Result<File> {
    // SAFETY: eventfd2 takes an initial counter and flags; no pointers.
    let fd = check(unsafe { syscall6(nr::EVENTFD2, 0, EFD_CLOEXEC | EFD_NONBLOCK, 0, 0, 0, 0) })?;
    // SAFETY: freshly created fd, owned by nobody else; File takes over
    // closing it and gives us safe Read/Write.
    Ok(unsafe { File::from_raw_fd(fd as RawFd) })
}

#[repr(C)]
struct Rlimit64 {
    cur: u64,
    max: u64,
}

const RLIMIT_NOFILE: usize = 7;

/// Raise this process's soft open-file limit to its hard limit (the
/// high-fanout paths hold thousands of sockets; stock soft limits are
/// often 1024). Best effort: returns the resulting soft limit.
pub fn raise_nofile_limit() -> io::Result<u64> {
    let mut old = Rlimit64 { cur: 0, max: 0 };
    // SAFETY: a null new-limit pointer makes prlimit64 a pure read; `old`
    // outlives the call and is sized as the kernel expects (two u64s).
    check(unsafe {
        syscall6(nr::PRLIMIT64, 0, RLIMIT_NOFILE, 0, std::ptr::addr_of_mut!(old) as usize, 0, 0)
    })?;
    if old.cur >= old.max {
        return Ok(old.cur);
    }
    let new = Rlimit64 { cur: old.max, max: old.max };
    // SAFETY: `new` outlives the call; the null old-limit pointer tells
    // the kernel not to write anything back.
    check(unsafe {
        syscall6(nr::PRLIMIT64, 0, RLIMIT_NOFILE, std::ptr::addr_of!(new) as usize, 0, 0, 0)
    })?;
    Ok(new.cur)
}

const SOL_SOCKET: usize = 1;
const SO_RCVBUF: usize = 8;

/// Clamp a socket's kernel receive buffer (used by the slow-reader test
/// to make the writer-backlog bound reachable with a deterministic amount
/// of traffic, independent of the host's tcp autotuning defaults).
pub fn set_recv_buf(fd: RawFd, bytes: u32) -> io::Result<()> {
    let val: u32 = bytes;
    // SAFETY: `val` outlives the call and optlen (arg 5) matches its
    // size; SO_RCVBUF only reads the option value.
    check(unsafe {
        syscall6(
            nr::SETSOCKOPT,
            fd as usize,
            SOL_SOCKET,
            SO_RCVBUF,
            std::ptr::addr_of!(val) as usize,
            std::mem::size_of::<u32>(),
            0,
        )
    })?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use std::io::{Read, Write};

    use super::*;

    #[test]
    fn eventfd_wakes_epoll_and_drains() {
        let ep = Epoll::new().unwrap();
        let mut efd = eventfd().unwrap();
        ep.add(efd.as_raw_fd(), EPOLLIN, 42).unwrap();

        // Nothing pending: a zero-timeout wait returns no events.
        let mut evs = vec![EpollEvent::zeroed(); 8];
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);

        // A write makes it readable, with our token echoed back.
        (&efd).write_all(&1u64.to_ne_bytes()).unwrap();
        let n = ep.wait(&mut evs, 1000).unwrap();
        assert_eq!(n, 1);
        // Copy packed fields into locals before asserting: assert_eq!
        // takes references, and referencing a field of the (x86_64-packed)
        // EpollEvent is a compile error (E0793); by-value reads are fine.
        let data = evs[0].data;
        let events = evs[0].events;
        assert_eq!(data, 42);
        assert_ne!(events & EPOLLIN, 0);

        // Draining resets it; a second drain would block, so the
        // nonblocking read errors with WouldBlock instead.
        let mut buf = [0u8; 8];
        efd.read_exact(&mut buf).unwrap();
        assert_eq!(u64::from_ne_bytes(buf), 1);
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);
        let err = efd.read(&mut buf).map(|_| ()).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::WouldBlock);
    }

    #[test]
    fn interest_can_be_modified_and_deleted() {
        let ep = Epoll::new().unwrap();
        let efd = eventfd().unwrap();
        ep.add(efd.as_raw_fd(), EPOLLIN, 7).unwrap();
        (&efd).write_all(&1u64.to_ne_bytes()).unwrap();

        // Interest masked off: no event even though the fd is readable.
        ep.modify(efd.as_raw_fd(), 0, 7).unwrap();
        let mut evs = vec![EpollEvent::zeroed(); 4];
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);

        // Re-armed: the event comes back.
        ep.modify(efd.as_raw_fd(), EPOLLIN, 9).unwrap();
        assert_eq!(ep.wait(&mut evs, 1000).unwrap(), 1);
        let data = evs[0].data;
        assert_eq!(data, 9);

        ep.del(efd.as_raw_fd()).unwrap();
        assert_eq!(ep.wait(&mut evs, 0).unwrap(), 0);
    }

    #[test]
    fn nofile_limit_is_raisable() {
        let cur = raise_nofile_limit().unwrap();
        assert!(cur >= 256, "soft NOFILE limit suspiciously low: {cur}");
    }
}
