//! Experiment support shared by the benches and examples: artifact
//! loading, an embedding cache (embeddings are input-deterministic, so the
//! FSL/CL protocols reuse them across tasks instead of re-running the
//! TCN), the FSL/CL evaluation protocols, and the prior-work constants
//! tables from the paper used in the comparison figures.

use std::collections::HashMap;
use std::path::PathBuf;

use anyhow::{anyhow, Context, Result};

use crate::data::EvalPool;
use crate::golden;
use crate::model::QuantModel;
use crate::protonet::ProtoHead;
use crate::util::rng::Rng;
use crate::util::stats;

/// Locate artifacts or explain how to produce them.
pub fn require_artifacts() -> Result<PathBuf> {
    let dir = crate::artifacts_dir();
    if dir.join("manifest.json").exists() {
        Ok(dir)
    } else {
        Err(anyhow!(
            "artifacts not found at {} — run `make artifacts` first",
            dir.display()
        ))
    }
}

pub fn load_model(name: &str) -> Result<QuantModel> {
    let dir = require_artifacts()?;
    QuantModel::load(&dir.join(format!("{name}.model.json")))
        .with_context(|| format!("loading model {name}"))
}

pub fn load_pool(name: &str) -> Result<EvalPool> {
    let dir = require_artifacts()?;
    EvalPool::load(&dir.join(format!("eval_{name}.json")))
        .with_context(|| format!("loading eval pool {name}"))
}

// ---------------------------------------------------------------------------
// Embedding cache
// ---------------------------------------------------------------------------

/// Caches golden-model embeddings per (class, sample); the TCN embedding of
/// a pool sample never changes, so every protocol step after the first is a
/// cheap FC operation — the same reuse the chip gets from its activation
/// memory during learning.
pub struct EmbedCache<'a> {
    pub model: &'a QuantModel,
    pub pool: &'a EvalPool,
    cache: HashMap<(usize, usize), Vec<u8>>,
}

impl<'a> EmbedCache<'a> {
    pub fn new(model: &'a QuantModel, pool: &'a EvalPool) -> Self {
        EmbedCache { model, pool, cache: HashMap::new() }
    }

    pub fn embedding(&mut self, class: usize, sample: usize) -> Result<&Vec<u8>> {
        if !self.cache.contains_key(&(class, sample)) {
            let emb = golden::embed(self.model, self.pool.sample(class, sample))?;
            self.cache.insert((class, sample), emb);
        }
        Ok(&self.cache[&(class, sample)])
    }

    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }
}

// ---------------------------------------------------------------------------
// FSL protocol (paper Table I)
// ---------------------------------------------------------------------------

/// Accuracy of `n_tasks` independent N-way k-shot episodes (mean, 95 % CI).
pub fn fsl_eval(
    cache: &mut EmbedCache,
    n_way: usize,
    k_shot: usize,
    n_query: usize,
    n_tasks: usize,
    seed: u64,
) -> Result<(f64, f64)> {
    let mut rng = Rng::new(seed);
    let mut accs = Vec::with_capacity(n_tasks);
    let spc = cache.pool.samples_per_class;
    let n_classes = cache.pool.classes;
    for _ in 0..n_tasks {
        let classes = rng.choose_distinct(n_classes, n_way);
        let mut head = ProtoHead::new(cache.model.embed_dim);
        let mut queries: Vec<(usize, Vec<u8>)> = Vec::new();
        for (way, &c) in classes.iter().enumerate() {
            let ids = rng.choose_distinct(spc, k_shot + n_query);
            let shots: Vec<Vec<u8>> = ids[..k_shot]
                .iter()
                .map(|&i| cache.embedding(c, i).cloned())
                .collect::<Result<_>>()?;
            head.learn_way(&shots)?;
            for &i in &ids[k_shot..] {
                queries.push((way, cache.embedding(c, i)?.clone()));
            }
        }
        let correct = queries
            .iter()
            .filter(|(way, q)| head.classify(q) == *way)
            .count();
        accs.push(correct as f64 / queries.len() as f64);
    }
    Ok((stats::mean(&accs), stats::ci95(&accs)))
}

// ---------------------------------------------------------------------------
// CL protocol (paper Fig. 15)
// ---------------------------------------------------------------------------

/// One continual-learning run: classes are learned one at a time (k shots
/// each); after reaching each checkpoint in `eval_at`, accuracy over
/// `n_query` held-out queries per learned class is recorded.
pub fn cl_run(
    cache: &mut EmbedCache,
    k_shot: usize,
    n_query: usize,
    eval_at: &[usize],
    seed: u64,
) -> Result<Vec<(usize, f64)>> {
    let mut rng = Rng::new(seed);
    let n_classes = cache.pool.classes;
    let spc = cache.pool.samples_per_class;
    let max_ways = *eval_at.iter().max().unwrap_or(&0);
    assert!(max_ways <= n_classes, "CL wants {max_ways} ways, pool has {n_classes}");
    let mut order: Vec<usize> = (0..n_classes).collect();
    rng.shuffle(&mut order);
    let order = &order[..max_ways];

    let mut head = ProtoHead::new(cache.model.embed_dim);
    // fixed per-class shot/query sample ids
    let mut splits = Vec::with_capacity(max_ways);
    for &c in order {
        let ids = rng.choose_distinct(spc, k_shot + n_query);
        splits.push((c, ids));
    }
    let mut out = Vec::new();
    for (w, (c, ids)) in splits.iter().enumerate() {
        let shots: Vec<Vec<u8>> = ids[..k_shot]
            .iter()
            .map(|&i| cache.embedding(*c, i).cloned())
            .collect::<Result<_>>()?;
        head.learn_way(&shots)?;
        let ways_so_far = w + 1;
        if eval_at.contains(&ways_so_far) {
            let mut correct = 0usize;
            let mut total = 0usize;
            for (way, (cc, iids)) in splits.iter().take(ways_so_far).enumerate() {
                for &i in &iids[k_shot..] {
                    let q = cache.embedding(*cc, i)?.clone();
                    correct += usize::from(head.classify(&q) == way);
                    total += 1;
                }
            }
            out.push((ways_so_far, correct as f64 / total as f64));
        }
    }
    Ok(out)
}

/// Average accuracy over a CL curve (the paper's "avg." metric).
pub fn cl_average(curve: &[(usize, f64)]) -> f64 {
    stats::mean(&curve.iter().map(|(_, a)| *a).collect::<Vec<_>>())
}

// ---------------------------------------------------------------------------
// KWS protocol (paper Figs. 12/17)
// ---------------------------------------------------------------------------

/// Full-pool KWS evaluation: accuracy + confusion matrix (true x pred).
pub fn kws_eval(model: &QuantModel, pool: &EvalPool) -> Result<(f64, Vec<Vec<usize>>)> {
    let n = pool.classes;
    let mut conf = vec![vec![0usize; n]; n];
    let mut correct = 0usize;
    let mut total = 0usize;
    for c in 0..n {
        for s in 0..pool.samples_per_class {
            let (_, logits) = golden::forward(model, pool.sample(c, s))?;
            let pred = golden::argmax(&logits.ok_or_else(|| anyhow!("no head"))?);
            conf[c][pred] += 1;
            correct += usize::from(pred == c);
            total += 1;
        }
    }
    Ok((correct as f64 / total as f64, conf))
}

// ---------------------------------------------------------------------------
// Prior-work constants (paper Table II / Figs. 9, 12)
// ---------------------------------------------------------------------------

/// A row of the paper's SotA comparison (reported numbers, not ours).
#[derive(Debug, Clone)]
pub struct PriorWork {
    pub name: &'static str,
    pub venue: &'static str,
    pub technology: &'static str,
    pub kws_accuracy_pct: Option<f64>,
    pub kws_power_uw: Option<f64>,
    pub peak_gops: Option<f64>,
    pub peak_tops_w: Option<f64>,
    pub model_kb: Option<f64>,
    pub act_mem_kb: Option<f64>,
    pub max_input_len: Option<usize>,
    pub max_weights_k: Option<f64>,
}

/// KWS accelerators (Fig. 12 / Table II left columns).
pub fn kws_accelerators() -> Vec<PriorWork> {
    vec![
        PriorWork {
            name: "Vocell [10]", venue: "JSSC'20", technology: "65nm",
            kws_accuracy_pct: Some(90.87), kws_power_uw: Some(10.6),
            peak_gops: Some(0.13), peak_tops_w: Some(0.45), model_kb: Some(16.0),
            act_mem_kb: None, max_input_len: Some(62), max_weights_k: Some(32.0),
        },
        PriorWork {
            name: "Giraldo et al. [11]", venue: "TVLSI'21", technology: "65nm",
            kws_accuracy_pct: Some(91.9), kws_power_uw: Some(16.0),
            peak_gops: Some(0.26), peak_tops_w: None, model_kb: Some(30.0),
            act_mem_kb: Some(3.2), max_input_len: Some(60), max_weights_k: Some(60.0),
        },
        PriorWork {
            name: "TinyVers [12]", venue: "JSSC'23", technology: "22nm",
            kws_accuracy_pct: Some(93.3), kws_power_uw: Some(193.0),
            peak_gops: Some(17.6), peak_tops_w: Some(17.0), model_kb: Some(23.0),
            act_mem_kb: None, max_input_len: Some(60), max_weights_k: Some(400.0),
        },
        PriorWork {
            name: "UltraTrail [13]", venue: "TCAD'20", technology: "22nm",
            kws_accuracy_pct: Some(93.1), kws_power_uw: Some(8.2),
            peak_gops: Some(3.8), peak_tops_w: None, model_kb: Some(45.0),
            act_mem_kb: Some(1.2), max_input_len: Some(101), max_weights_k: Some(90.0),
        },
        PriorWork {
            name: "TCN-CUTIE [19]", venue: "IEEE Micro'23", technology: "22nm",
            kws_accuracy_pct: None, kws_power_uw: Some(12200.0),
            // 1036 TOP/s/W ternary — not comparable to 4/8-bit GOPS figures.
            peak_gops: None, peak_tops_w: None,
            model_kb: None, act_mem_kb: Some(8.0), max_input_len: Some(24), max_weights_k: None,
        },
        PriorWork {
            name: "Tan et al. [52]", venue: "JSSC'25", technology: "28nm",
            kws_accuracy_pct: Some(91.8), kws_power_uw: Some(1.73),
            peak_gops: None, peak_tops_w: None, model_kb: Some(11.0),
            act_mem_kb: None, max_input_len: Some(8000), max_weights_k: Some(32.8),
        },
    ]
}

/// FSL accelerators (Table II right columns): Omniglot accuracies.
#[derive(Debug, Clone)]
pub struct FslPrior {
    pub name: &'static str,
    pub end_to_end: bool,
    pub acc_5w1s: Option<f64>,
    pub acc_5w5s: Option<f64>,
    pub acc_20w1s: Option<f64>,
    pub acc_20w5s: Option<f64>,
    pub acc_32w1s: Option<f64>,
    pub model_size_kb: Option<f64>,
    pub max_classes: Option<usize>,
}

pub fn fsl_accelerators() -> Vec<FslPrior> {
    vec![
        FslPrior {
            name: "Kim et al. [7] (off-chip FP32 embedder)", end_to_end: false,
            acc_5w1s: Some(93.4), acc_5w5s: Some(98.3), acc_20w1s: None,
            acc_20w5s: None, acc_32w1s: None, model_size_kb: Some(7460.0),
            max_classes: Some(25),
        },
        FslPrior {
            name: "SAPIENS [8] (off-chip FP32 embedder)", end_to_end: false,
            acc_5w1s: None, acc_5w5s: None, acc_20w1s: None, acc_20w5s: None,
            acc_32w1s: Some(72.0), model_size_kb: Some(447.0), max_classes: Some(32),
        },
        FslPrior {
            name: "FSL-HDnn [9]", end_to_end: false,
            acc_5w1s: Some(79.0), acc_5w5s: None, acc_20w1s: None,
            acc_20w5s: Some(79.5), acc_32w1s: None, model_size_kb: Some(5500.0),
            max_classes: Some(128),
        },
    ]
}

/// The paper's own reported numbers ("this work"), for paper-vs-measured
/// rows in the benches.
pub struct PaperChameleon;

impl PaperChameleon {
    pub const FSL_5W1S: f64 = 96.8;
    pub const FSL_5W5S: f64 = 98.8;
    pub const FSL_20W1S: f64 = 89.1;
    pub const FSL_20W5S: f64 = 96.1;
    pub const FSL_32W1S: f64 = 83.3;
    pub const CL_250_10SHOT_FINAL: f64 = 82.2;
    pub const CL_250_10SHOT_AVG: f64 = 89.0;
    pub const KWS_MFCC_ACC: f64 = 93.3;
    pub const KWS_RAW_ACC: f64 = 86.4;
    pub const KWS_MFCC_POWER_UW: f64 = 3.1;
    pub const KWS_RAW_POWER_UW: f64 = 59.4;
    pub const PEAK_GOPS: f64 = 76.8;
    pub const PEAK_TOPS_W: f64 = 6.0;
    pub const MEM_REDUCTION_16K: f64 = 90.0;
    pub const COMPUTE_REDUCTION_16K: f64 = 1e4;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prior_tables_are_consistent() {
        assert_eq!(kws_accelerators().len(), 6);
        assert_eq!(fsl_accelerators().len(), 3);
        for p in kws_accelerators() {
            if let Some(a) = p.kws_accuracy_pct {
                assert!((50.0..100.0).contains(&a), "{}", p.name);
            }
        }
    }
}
