//! Evaluation-dataset loading: the hex-packed u4 sequence pools exported by
//! `python/compile/export_eval.py` (synthetic Omniglot meta-test classes and
//! the synthetic speech-commands test split).

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json;
use crate::util::rng::Rng;

/// A pool of labelled u4 sequences: `samples_per_class` sequences for each
/// of `classes` classes, each `[seq_len][in_channels]` row-major.
#[derive(Debug, Clone)]
pub struct EvalPool {
    pub name: String,
    pub seq_len: usize,
    pub in_channels: usize,
    pub classes: usize,
    pub samples_per_class: usize,
    pub in_shift: i32,
    pub class_names: Option<Vec<String>>,
    /// All sequences, `[class * samples_per_class + sample]`.
    data: Vec<Vec<u8>>,
}

impl EvalPool {
    pub fn load(path: &Path) -> Result<EvalPool> {
        let v = json::parse_file(path)?;
        let seq_len = v.req("seq_len")?.as_usize()?;
        let in_channels = v.req("in_channels")?.as_usize()?;
        let classes = v.req("classes")?.as_usize()?;
        let samples_per_class = v.req("samples_per_class")?.as_usize()?;
        let entries = v.req("data")?.as_arr()?;
        if entries.len() != classes * samples_per_class {
            bail!(
                "expected {} sequences, got {}",
                classes * samples_per_class,
                entries.len()
            );
        }
        let expect_len = seq_len * in_channels;
        let data = entries
            .iter()
            .map(|e| unpack_hex(e.as_str()?, expect_len))
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("unpacking {}", path.display()))?;
        Ok(EvalPool {
            name: v.req("name")?.as_str()?.to_string(),
            seq_len,
            in_channels,
            classes,
            samples_per_class,
            in_shift: v.req("in_shift")?.as_i64()? as i32,
            class_names: match v.get_nonnull("class_names") {
                Some(ns) => Some(
                    ns.as_arr()?
                        .iter()
                        .map(|n| Ok(n.as_str()?.to_string()))
                        .collect::<Result<Vec<_>>>()?,
                ),
                None => None,
            },
            data,
        })
    }

    pub fn sample(&self, class: usize, idx: usize) -> &[u8] {
        &self.data[class * self.samples_per_class + idx]
    }

    /// Sample an FSL episode: `n_way` distinct classes, `k_shot` support and
    /// `n_query` query samples each (disjoint). Returns
    /// `(class_ids, support[way][shot], query[way][q])` as slices.
    #[allow(clippy::type_complexity)]
    pub fn episode(
        &self,
        rng: &mut Rng,
        n_way: usize,
        k_shot: usize,
        n_query: usize,
    ) -> (Vec<usize>, Vec<Vec<&[u8]>>, Vec<Vec<&[u8]>>) {
        assert!(
            k_shot + n_query <= self.samples_per_class,
            "k+q exceeds pool depth"
        );
        let classes = rng.choose_distinct(self.classes, n_way);
        let mut sup = Vec::with_capacity(n_way);
        let mut qry = Vec::with_capacity(n_way);
        for &c in &classes {
            let ids = rng.choose_distinct(self.samples_per_class, k_shot + n_query);
            sup.push(ids[..k_shot].iter().map(|&i| self.sample(c, i)).collect());
            qry.push(ids[k_shot..].iter().map(|&i| self.sample(c, i)).collect());
        }
        (classes, sup, qry)
    }
}

fn unpack_hex(s: &str, expect_len: usize) -> Result<Vec<u8>> {
    if s.len() != expect_len {
        bail!("sequence length {} != expected {}", s.len(), expect_len);
    }
    s.bytes()
        .map(|b| match b {
            b'0'..=b'9' => Ok(b - b'0'),
            b'a'..=b'f' => Ok(b - b'a' + 10),
            _ => bail!("bad hex digit {:?}", b as char),
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unpack_hex_roundtrip() {
        let v = unpack_hex("0f3a", 4).unwrap();
        assert_eq!(v, vec![0, 15, 3, 10]);
        assert!(unpack_hex("0f", 4).is_err());
        assert!(unpack_hex("zz", 2).is_err());
    }

    fn tiny_pool() -> EvalPool {
        // 3 classes x 4 samples of [2][1] sequences.
        let data = (0..12u8).map(|i| vec![i % 16, (i + 1) % 16]).collect();
        EvalPool {
            name: "t".into(),
            seq_len: 2,
            in_channels: 1,
            classes: 3,
            samples_per_class: 4,
            in_shift: 0,
            class_names: None,
            data,
        }
    }

    #[test]
    fn episode_disjoint_support_query() {
        let pool = tiny_pool();
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let (classes, sup, qry) = pool.episode(&mut rng, 2, 2, 2);
            assert_eq!(classes.len(), 2);
            for w in 0..2 {
                for s in &sup[w] {
                    for q in &qry[w] {
                        assert!(
                            s.as_ptr() != q.as_ptr(),
                            "support and query share a sample"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn sample_indexing() {
        let pool = tiny_pool();
        assert_eq!(pool.sample(1, 0), &[4, 5]);
        assert_eq!(pool.sample(2, 3), &[11, 12]);
    }
}
