//! Quantized-network description: the interchange format written by
//! `python/compile/io_json.py` (`artifacts/<name>.model.json`), plus the
//! architecture math (receptive field, memory footprints) used by the
//! simulator, the baselines and the benches.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::quant;
use crate::util::json::{self, Value};

/// One integer conv / FC layer exactly as the chip sees it.
#[derive(Debug, Clone)]
pub struct QLayer {
    /// s4 log2 weight codes, row-major over `codes_shape`.
    pub codes: Vec<i8>,
    /// Conv: `[K, Cin, Cout]`; FC: `[Cin, Cout]`.
    pub codes_shape: Vec<usize>,
    /// 14-bit biases at accumulator scale, one per output channel.
    pub bias: Vec<i32>,
    /// OPE arithmetic right shift (>= 0).
    pub out_shift: i32,
    pub dilation: usize,
    pub relu: bool,
    /// Signed residual rescale into the accumulator domain (None = no residual).
    pub res_shift: Option<i32>,
    /// Optional 1x1 residual conv (u4 output at the block-input shift).
    pub res_codes: Option<Vec<i8>>,
    pub res_codes_shape: Option<Vec<usize>>,
    pub res_bias: Option<Vec<i32>>,
    pub res_out_shift: Option<i32>,
}

impl QLayer {
    pub fn kernel_size(&self) -> usize {
        if self.codes_shape.len() == 3 {
            self.codes_shape[0]
        } else {
            1
        }
    }

    pub fn c_in(&self) -> usize {
        self.codes_shape[self.codes_shape.len() - 2]
    }

    pub fn c_out(&self) -> usize {
        *self.codes_shape.last().unwrap()
    }

    /// Weight count including bias terms (paper counts both).
    pub fn param_count(&self) -> usize {
        let mut n = self.codes.len() + self.bias.len();
        if let Some(rc) = &self.res_codes {
            n += rc.len() + self.res_bias.as_ref().map_or(0, |b| b.len());
        }
        n
    }

    /// Macs per output timestep.
    pub fn macs_per_step(&self) -> usize {
        self.kernel_size() * self.c_in() * self.c_out()
    }

    fn from_json(v: &Value) -> Result<QLayer> {
        let out_shift = shift_from_json(v.req("out_shift")?, "out_shift", false)?;
        let res_shift = match v.get_nonnull("res_shift") {
            Some(s) => Some(shift_from_json(s, "res_shift", true)?),
            None => None,
        };
        let codes: Vec<i8> = v
            .req("codes")?
            .as_i32_vec()?
            .into_iter()
            .map(|c| {
                if !(-8..=7).contains(&c) {
                    bail!("weight code {c} out of s4 range");
                }
                Ok(c as i8)
            })
            .collect::<Result<_>>()?;
        let codes_shape = v.req("codes_shape")?.as_usize_vec()?;
        if codes.len() != codes_shape.iter().product::<usize>() {
            bail!("codes length does not match shape {:?}", codes_shape);
        }
        let bias = v.req("bias")?.as_i32_vec()?;
        for &b in &bias {
            if b < quant::BIAS_MIN || b > quant::BIAS_MAX {
                bail!("bias {b} out of 14-bit range");
            }
        }
        let (res_codes, res_codes_shape, res_bias, res_out_shift) =
            match v.get_nonnull("res_codes") {
                Some(rc) => (
                    Some(
                        rc.as_i32_vec()?
                            .into_iter()
                            .map(|c| c as i8)
                            .collect::<Vec<i8>>(),
                    ),
                    Some(v.req("res_codes_shape")?.as_usize_vec()?),
                    Some(v.req("res_bias")?.as_i32_vec()?),
                    Some(shift_from_json(v.req("res_out_shift")?, "res_out_shift", false)?),
                ),
                None => (None, None, None, None),
            };
        Ok(QLayer {
            codes,
            codes_shape,
            bias,
            out_shift,
            dilation: v.req("dilation")?.as_usize()?,
            relu: v.req("relu")?.as_bool()?,
            res_shift,
            res_codes,
            res_codes_shape,
            res_bias,
            res_out_shift,
        })
    }
}

/// Parse one shift field, rejecting values outside the shift ops'
/// documented domain (`quant::MAX_SHIFT`) **before** the i64 -> i32 cast
/// can truncate them into range — a corrupt artifact must fail at load,
/// not panic (or wrap) a worker mid-request.
fn shift_from_json(v: &Value, key: &str, signed: bool) -> Result<i32> {
    let s = v.as_i64()?;
    let lo = if signed { -(quant::MAX_SHIFT as i64) } else { 0 };
    let hi = quant::MAX_SHIFT as i64;
    if !(lo..=hi).contains(&s) {
        bail!("{key} {s} outside the valid shift range [{lo}, {hi}]");
    }
    Ok(s as i32)
}

/// A full quantized Chameleon-deployable network.
#[derive(Debug, Clone)]
pub struct QuantModel {
    pub name: String,
    pub in_channels: usize,
    pub seq_len: usize,
    pub channels: Vec<usize>,
    pub kernel_size: usize,
    pub embed_dim: usize,
    pub n_classes: Option<usize>,
    pub in_shift: i32,
    pub embed_shift: i32,
    /// TCN conv layers, two per residual block.
    pub layers: Vec<QLayer>,
    /// Embedding FC (u4 output).
    pub embed: QLayer,
    /// Optional classifier head (raw logits). For PN learning this slot is
    /// rewritten on-"chip" by the prototypical parameter extractor.
    pub head: Option<QLayer>,
}

impl QuantModel {
    pub fn load(path: &Path) -> Result<QuantModel> {
        let v = json::parse_file(path)?;
        Self::from_json(&v).with_context(|| format!("loading model {}", path.display()))
    }

    pub fn from_json(v: &Value) -> Result<QuantModel> {
        let layers = v
            .req("layers")?
            .as_arr()?
            .iter()
            .map(QLayer::from_json)
            .collect::<Result<Vec<_>>>()?;
        let channels = v.req("channels")?.as_usize_vec()?;
        if layers.len() != channels.len() * 2 {
            bail!("expected {} layers, got {}", channels.len() * 2, layers.len());
        }
        Ok(QuantModel {
            name: v.req("name")?.as_str()?.to_string(),
            in_channels: v.req("in_channels")?.as_usize()?,
            seq_len: v.req("seq_len")?.as_usize()?,
            channels,
            kernel_size: v.req("kernel_size")?.as_usize()?,
            embed_dim: v.req("embed_dim")?.as_usize()?,
            n_classes: match v.get_nonnull("n_classes") {
                Some(n) => Some(n.as_usize()?),
                None => None,
            },
            in_shift: shift_from_json(v.req("in_shift")?, "in_shift", true)?,
            embed_shift: shift_from_json(v.req("embed_shift")?, "embed_shift", true)?,
            layers,
            embed: QLayer::from_json(v.req("embed")?)?,
            head: match v.get_nonnull("head") {
                Some(h) => Some(QLayer::from_json(h)?),
                None => None,
            },
        })
    }

    pub fn n_blocks(&self) -> usize {
        self.channels.len()
    }

    /// Receptive field: `R = 1 + sum_l (k-1) * d_l` over all conv layers.
    pub fn receptive_field(&self) -> usize {
        1 + self
            .layers
            .iter()
            .map(|l| (l.kernel_size() - 1) * l.dilation)
            .sum::<usize>()
    }

    pub fn param_count(&self) -> usize {
        let mut n: usize = self.layers.iter().map(|l| l.param_count()).sum();
        n += self.embed.param_count();
        if let Some(h) = &self.head {
            n += h.param_count();
        }
        n
    }

    /// Total MACs for one full-sequence inference (dense, no dilation skip).
    pub fn dense_macs(&self) -> u64 {
        let per_step: u64 = self.layers.iter().map(|l| l.macs_per_step() as u64).sum();
        per_step * self.seq_len as u64
            + self.embed.macs_per_step() as u64
            + self.head.as_ref().map_or(0, |h| h.macs_per_step() as u64)
    }

    /// Chameleon's greedy FIFO activation-memory estimate in bytes:
    /// with dilation-aware skipping each layer only ever holds ~`k + 1`
    /// live input timesteps (the paper's Fig. 8(b) lifetimes), regardless
    /// of dilation — this is where the 90x reduction at 16 k steps comes
    /// from. The cycle simulator measures the exact high-water mark; this
    /// is the closed-form estimate used by the baselines comparison.
    pub fn fifo_activation_bytes(&self) -> usize {
        let mut bits = 0usize;
        for l in &self.layers {
            bits += (l.kernel_size() + 1) * l.c_in() * 4;
            if l.res_shift.is_some() {
                // residual tap: one block-input row held until the merge
                bits += l.c_in() * 4;
            }
        }
        // final-timestep feature vector for the embedding FC
        bits += self.embed.c_in() * 4;
        bits / 8
    }

    /// Dense streaming FIFO requirement (Giraldo-style `(k-1)d + 1` rings):
    /// what Chameleon would need *without* dilation-aware skipping when an
    /// output is produced for every input timestep.
    pub fn dense_fifo_activation_bytes(&self) -> usize {
        let mut bits = 0usize;
        for l in &self.layers {
            let hist = (l.kernel_size() - 1) * l.dilation + 1;
            bits += hist * l.c_in() * 4;
        }
        bits += self.embed.c_in() * 4;
        bits / 8
    }

    /// Names-and-sizes inventory (for reports).
    pub fn describe(&self) -> String {
        format!(
            "{}: {} blocks (k={}, ch={:?}), RF={}, params={}, seq_len={}, V={}",
            self.name,
            self.n_blocks(),
            self.kernel_size,
            self.channels,
            self.receptive_field(),
            self.param_count(),
            self.seq_len,
            self.embed_dim,
        )
    }
}

/// Deterministic pseudo-random s4 codes for the built-in demo models.
fn demo_codes(n: usize, seed: i32) -> Vec<i8> {
    (0..n).map(|i| (((i as i32 * 7 + seed) % 9) - 4) as i8).collect()
}

/// Built-in demo model (no artifacts needed): two residual blocks —
/// identity residual in block 0, 1x1-conv residual (channel change 4 -> 6)
/// in block 1 — with mildly varied codes so the full mixed-sign shift
/// arithmetic is exercised. Headless: classification goes through a
/// session's learned prototypical head (FSL/CL serving).
///
/// Used as the default model of the `serve`/`loadgen` subcommands and by
/// the unit/integration tests, so the whole serving stack runs end to end
/// on a fresh checkout without `make artifacts`.
pub fn demo_tiny() -> QuantModel {
    let conv = |k: usize, cin: usize, cout: usize, dil: usize, res: Option<i32>, seed: i32| QLayer {
        codes: demo_codes(k * cin * cout, seed),
        codes_shape: vec![k, cin, cout],
        bias: (0..cout).map(|c| (c as i32 * 3 - 4) * 2).collect(),
        out_shift: 4,
        dilation: dil,
        relu: true,
        res_shift: res,
        res_codes: None,
        res_codes_shape: None,
        res_bias: None,
        res_out_shift: None,
    };
    let mut l_res = conv(3, 6, 6, 2, Some(1), 5);
    l_res.res_codes = Some(demo_codes(4 * 6, 3));
    l_res.res_codes_shape = Some(vec![1, 4, 6]);
    l_res.res_bias = Some(vec![1; 6]);
    l_res.res_out_shift = Some(2);
    QuantModel {
        name: "tiny".into(),
        in_channels: 4,
        seq_len: 16,
        channels: vec![4, 6],
        kernel_size: 3,
        embed_dim: 8,
        n_classes: None,
        in_shift: 0,
        embed_shift: 0,
        layers: vec![
            conv(3, 4, 4, 1, None, 1),
            conv(3, 4, 4, 1, Some(0), 2),
            conv(3, 4, 6, 2, None, 4),
            l_res,
        ],
        embed: QLayer {
            codes: demo_codes(6 * 8, 6),
            codes_shape: vec![6, 8],
            bias: vec![0; 8],
            out_shift: 4,
            dilation: 1,
            relu: true,
            res_shift: None,
            res_codes: None,
            res_codes_shape: None,
            res_bias: None,
            res_out_shift: None,
        },
        head: None,
    }
}

/// [`demo_tiny`] plus a fixed 5-class classifier head, so the plain
/// `Classify` path (KWS-style serving with the built-in head) also works
/// without artifacts. Predictions are deterministic but arbitrary — the
/// point is exercising the datapath, not accuracy.
pub fn demo_tiny_kws() -> QuantModel {
    let mut m = demo_tiny();
    m.name = "tiny_kws".into();
    m.n_classes = Some(5);
    m.head = Some(QLayer {
        codes: demo_codes(8 * 5, 7),
        codes_shape: vec![8, 5],
        bias: (0..5).map(|c| c * 7 - 14).collect(),
        out_shift: 0,
        dilation: 1,
        relu: false,
        res_shift: None,
        res_codes: None,
        res_codes_shape: None,
        res_bias: None,
        res_out_shift: None,
    });
    m
}

#[cfg(test)]
pub mod tests {
    use super::*;

    /// The canonical tiny test model — the built-in demo model.
    pub fn tiny_model() -> QuantModel {
        demo_tiny()
    }

    #[test]
    fn receptive_field_formula() {
        let m = tiny_model();
        // layers: (3-1)*1 + (3-1)*1 + (3-1)*2 + (3-1)*2 = 12; +1 = 13
        assert_eq!(m.receptive_field(), 13);
    }

    #[test]
    fn param_count_counts_everything() {
        let m = tiny_model();
        let expect = (3 * 4 * 4 + 4)
            + (3 * 4 * 4 + 4)
            + (3 * 4 * 6 + 6)
            + (3 * 6 * 6 + 6)
            + (4 * 6 + 6) // 1x1 residual conv
            + (6 * 8 + 8);
        assert_eq!(m.param_count(), expect);
    }

    #[test]
    fn json_roundtrip_via_text() {
        // Minimal JSON document for one-layer model exercise of the loader.
        let doc = r#"{
            "name": "t", "in_channels": 1, "seq_len": 4, "channels": [2],
            "kernel_size": 2, "embed_dim": 2, "n_classes": null,
            "in_shift": 0, "embed_shift": 0, "act_shifts": [0],
            "layers": [
                {"codes": [1,1,1,1], "codes_shape": [2,1,2], "bias": [0,0],
                 "out_shift": 2, "dilation": 1, "relu": true, "res_shift": null,
                 "res_codes": null, "res_codes_shape": null, "res_bias": null,
                 "res_out_shift": null},
                {"codes": [1,1,1,1,1,1,1,1], "codes_shape": [2,2,2], "bias": [0,0],
                 "out_shift": 2, "dilation": 1, "relu": true, "res_shift": 0,
                 "res_codes": null, "res_codes_shape": null, "res_bias": null,
                 "res_out_shift": null}
            ],
            "embed": {"codes": [1,1,1,1], "codes_shape": [2,2], "bias": [0,0],
                      "out_shift": 2, "dilation": 1, "relu": true, "res_shift": null,
                      "res_codes": null, "res_codes_shape": null, "res_bias": null,
                      "res_out_shift": null},
            "head": null
        }"#;
        let v = json::parse(doc).unwrap();
        let m = QuantModel::from_json(&v).unwrap();
        assert_eq!(m.name, "t");
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.layers[1].res_shift, Some(0));
        assert!(m.head.is_none());
    }

    #[test]
    fn loader_rejects_out_of_range_shifts() {
        // A corrupt artifact must fail at load, not panic a worker later:
        // out_shift >= 32 (or huge values that would truncate back into
        // range on the i64 -> i32 cast) and negative unsigned shifts are
        // all rejected.
        let doc = |out_shift: &str, res_shift: &str| {
            format!(
                r#"{{
                "name": "t", "in_channels": 1, "seq_len": 4, "channels": [],
                "kernel_size": 2, "embed_dim": 2, "n_classes": null,
                "in_shift": 0, "embed_shift": 0, "layers": [],
                "embed": {{"codes": [1], "codes_shape": [1,1], "bias": [0],
                          "out_shift": {out_shift}, "dilation": 1, "relu": true,
                          "res_shift": {res_shift}, "res_codes": null,
                          "res_codes_shape": null, "res_bias": null,
                          "res_out_shift": null}},
                "head": null
            }}"#
            )
        };
        for bad in ["32", "99", "-1", "4294967296"] {
            let v = json::parse(&doc(bad, "null")).unwrap();
            assert!(QuantModel::from_json(&v).is_err(), "out_shift {bad} must be rejected");
        }
        for bad in ["32", "-32", "4294967296"] {
            let v = json::parse(&doc("0", bad)).unwrap();
            assert!(QuantModel::from_json(&v).is_err(), "res_shift {bad} must be rejected");
        }
        // In-range values (signed res_shift) still load.
        let v = json::parse(&doc("31", "-31")).unwrap();
        let m = QuantModel::from_json(&v).unwrap();
        assert_eq!(m.embed.out_shift, 31);
        assert_eq!(m.embed.res_shift, Some(-31));
    }

    #[test]
    fn loader_rejects_bad_codes() {
        let doc = r#"{
            "name": "t", "in_channels": 1, "seq_len": 4, "channels": [],
            "kernel_size": 2, "embed_dim": 2, "n_classes": null,
            "in_shift": 0, "embed_shift": 0, "layers": [],
            "embed": {"codes": [99], "codes_shape": [1,1], "bias": [0],
                      "out_shift": 0, "dilation": 1, "relu": true, "res_shift": null,
                      "res_codes": null, "res_codes_shape": null, "res_bias": null,
                      "res_out_shift": null},
            "head": null
        }"#;
        let v = json::parse(doc).unwrap();
        assert!(QuantModel::from_json(&v).is_err());
    }
}
