"""PN -> FC reformulation tests (paper Eq. 3-8): float equivalence of the
FC form to nearest-prototype classification, and the quantized (log2)
variant's properties."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import protonet as P
from compile import quantlib as ql

SETTINGS = dict(max_examples=40, deadline=None)


@settings(**SETTINGS)
@given(
    n_way=st.integers(2, 8),
    k_shot=st.integers(1, 5),
    dim=st.integers(2, 32),
    seed=st.integers(0, 2**31 - 1),
)
def test_fc_form_equals_nearest_prototype_float(n_way, k_shot, dim, seed):
    """Eq. 6: argmax(W.x + b) == argmin_j ||proto_j - x||^2 exactly."""
    rng = np.random.default_rng(seed)
    sup = rng.normal(size=(n_way * k_shot, dim)).astype(np.float32)
    q = rng.normal(size=(3, dim)).astype(np.float32)
    w, b = P.pn_to_fc_float(jnp.asarray(sup), n_way, k_shot)
    fc_pred = np.asarray(P.classify_float_fc(jnp.asarray(q), w, b))
    protos = sup.reshape(n_way, k_shot, dim).mean(1)
    d = ((q[:, None, :] - protos[None]) ** 2).sum(-1)
    np_pred = d.argmin(1)
    assert (fc_pred == np_pred).all()


def test_quant_fc_weights_are_log2_of_preshifted_sum():
    sup = np.asarray([[4, 8, 0, 2], [4, 8, 0, 2]], np.int32)  # 2 shots, 1 way
    codes, bias = P.pn_to_fc_quant(sup, n_way=1, k_shot=2)
    # sum = [8,16,0,4]; preshift ceil(log2 2)=1 -> [4,8,0,2]
    dec = np.asarray(ql.log2_decode(jnp.asarray(codes[:, 0])))
    assert (dec == [4, 8, 0, 2]).all()
    # bias = -(sum of squares)/2 = -(16+64+0+4)/2 = -42
    assert bias[0] == -42


@settings(**SETTINGS)
@given(
    n_way=st.integers(2, 6),
    dim=st.integers(4, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_quant_classify_matches_nearest_decoded(n_way, dim, seed):
    """One-shot: FC argmax equals argmin distance to decoded prototypes,
    up to the half-LSB floor of the odd-sum bias (distance slack <= 1)."""
    rng = np.random.default_rng(seed)
    sup = rng.integers(0, 16, (n_way, dim)).astype(np.int32)
    codes, bias = P.pn_to_fc_quant(sup, n_way=n_way, k_shot=1)
    q = rng.integers(0, 16, dim).astype(np.int32)
    pred, _ = P.classify_quant_fc(q, codes, bias)
    dec = np.stack([
        np.asarray(ql.log2_decode(jnp.asarray(codes[:, j]))) for j in range(n_way)
    ])
    d = ((q[None] - dec) ** 2).sum(1)
    assert d[pred] <= d.min() + 1


def test_preshift_values():
    assert P.proto_preshift(1) == 0
    assert P.proto_preshift(2) == 1
    assert P.proto_preshift(5) == 3
    assert P.proto_preshift(10) == 4


def test_bias_saturates_at_14_bits():
    sup = np.full((1, 256), 15, np.int32)  # extreme: all-max embedding
    codes, bias = P.pn_to_fc_quant(sup, 1, 1)
    assert bias[0] == ql.BIAS_MIN  # saturated, not wrapped


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_adam_decreases_simple_quadratic(seed):
    rng = np.random.default_rng(seed)
    target = jnp.asarray(rng.normal(size=4).astype(np.float32))
    params = {"w": jnp.zeros(4)}
    opt = P.adam_init(params)
    import jax

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    l0 = float(loss(params))
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, opt = P.adam_update(params, g, opt, lr=0.1)
    assert float(loss(params)) < l0 * 0.2
