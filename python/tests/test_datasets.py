"""Synthetic dataset generators: determinism, shapes, class structure, and
the episode protocol (support/query disjointness, class disjointness)."""

import numpy as np

from compile import datasets as D


def test_omniglot_shapes_and_determinism():
    ds = D.SyntheticOmniglot(10)
    a = ds.sample(3, 5)
    b = ds.sample(3, 5)
    assert a.shape == (784, 1)
    assert (a == b).all(), "samples must be deterministic"
    assert a.min() >= 0.0 and a.max() <= 1.0


def test_omniglot_class_prefix_stable():
    # class i must be identical regardless of the total class count (the
    # meta-test export relies on this).
    a = D.SyntheticOmniglot(10).sample(4, 0)
    b = D.SyntheticOmniglot(50).sample(4, 0)
    assert (a == b).all()


def test_omniglot_classes_differ():
    ds = D.SyntheticOmniglot(6)
    dists = []
    for c in range(1, 6):
        dists.append(np.abs(ds.sample(0, 0) - ds.sample(c, 0)).mean())
    assert min(dists) > 0.005, "classes must be distinguishable"


def test_omniglot_episode_protocol():
    ds = D.SyntheticOmniglot(12)
    rng = np.random.default_rng(0)
    sup, qry, classes = ds.episode(rng, n_way=4, k_shot=2, n_query=3)
    assert sup.shape == (4, 2, 784, 1)
    assert qry.shape == (4, 3, 784, 1)
    assert len(set(classes.tolist())) == 4
    pool = np.asarray([5, 6, 7, 8])
    _, _, classes = ds.episode(rng, 3, 1, 1, class_pool=pool)
    assert set(classes.tolist()) <= set(pool.tolist())


def test_speech_raw_and_mfcc_shapes():
    ds = D.SyntheticSpeechCommands()
    cfg = ds.cfg
    raw = ds.sample(0, 0, "raw")
    assert raw.shape == (cfg.n_samples, 1)
    assert np.abs(raw).max() <= 1.0
    mfcc = ds.sample(0, 0, "mfcc")
    assert mfcc.shape == (cfg.n_frames, cfg.n_mfcc)
    assert cfg.n_frames == 63  # KWS-standard frame count


def test_speech_determinism_and_12_classes():
    ds = D.SyntheticSpeechCommands()
    assert D.N_CLASSES == 12
    assert D.CLASSES[-2:] == ["unknown", "silence"]
    a = ds.sample(5, 7, "raw")
    b = ds.sample(5, 7, "raw")
    assert (a == b).all()


def test_silence_is_quieter_than_keywords():
    ds = D.SyntheticSpeechCommands()
    kw_energy = np.mean([np.abs(ds.sample(c, i, "raw")).mean() for c in range(4) for i in range(3)])
    sil_energy = np.mean([np.abs(ds.sample(11, i, "raw")).mean() for i in range(3)])
    assert sil_energy < kw_energy


def test_batch_and_fixed_split():
    ds = D.SyntheticSpeechCommands()
    rng = np.random.default_rng(1)
    x, y = ds.batch(rng, 8, "mfcc")
    assert x.shape[0] == 8 and y.shape == (8,)
    xs, ys = ds.fixed_split(2, "mfcc", base=100)
    assert xs.shape[0] == 24
    assert (np.bincount(ys) == 2).all()
