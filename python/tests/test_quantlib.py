"""Quantization-grammar unit tests: codecs, saturation, OPE semantics —
the python half of the cross-language contract with rust/src/quant."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import quantlib as ql

SETTINGS = dict(max_examples=50, deadline=None)


def test_log2_decode_table():
    codes = jnp.arange(-8, 8)
    vals = np.asarray(ql.log2_decode(codes))
    assert vals[8] == 0  # code 0
    assert vals[9] == 1 and vals[15] == 64  # codes 1..7
    assert vals[7] == -1 and vals[0] == -128  # codes -1..-8


def test_encode_decode_fixpoint():
    for c in range(-8, 8):
        v = int(ql.log2_decode(jnp.asarray(c)))
        assert int(ql.log2_encode_int(jnp.asarray(v))) == c or v == 0


@settings(**SETTINGS)
@given(v=st.integers(-4096, 4096))
def test_encode_int_is_nearest(v):
    got = int(ql.log2_decode(ql.log2_encode_int(jnp.asarray(v))))
    if -128 <= v <= 64:
        cands = [0] + [2**e for e in range(7)] + [-(2**e) for e in range(8)]
        best = min(abs(v - c) for c in cands)
        assert abs(v - got) <= best


@settings(**SETTINGS)
@given(act=st.integers(0, 15), code=st.integers(-8, 7))
def test_product_fits_12_bits(act, code):
    p = int(ql.shift_product(jnp.asarray(act), jnp.asarray(code)))
    assert -2048 <= p <= 2047


def test_sat_bounds():
    assert int(ql.sat_acc(jnp.asarray(10**6))) == 131071
    assert int(ql.sat_acc(jnp.asarray(-(10**6)))) == -131072
    assert int(ql.sat_bias(jnp.asarray(10**5))) == 8191
    assert int(ql.sat_bias(jnp.asarray(-(10**5)))) == -8192


def test_rounding_shift():
    assert int(ql.rounding_shift_right(jnp.asarray(7), 2)) == 2
    assert int(ql.rounding_shift_right(jnp.asarray(6), 2)) == 2
    assert int(ql.rounding_shift_right(jnp.asarray(5), 2)) == 1
    assert int(ql.rounding_shift_right(jnp.asarray(-6), 2)) == -1
    assert int(ql.rounding_shift_right(jnp.asarray(9), 0)) == 9


def test_ope_residual_and_clamp():
    y = int(ql.ope(jnp.asarray(100), jnp.asarray(20), 3, relu=True,
                   residual=jnp.asarray(3), res_shift=2))
    assert y == min(max((100 + 20 + 12 + 4) >> 3, 0), 15)
    # non-relu: raw saturated total
    y = int(ql.ope(jnp.asarray(131000), jnp.asarray(8191), 0, relu=False))
    assert y == 131071


@settings(**SETTINGS)
@given(x=st.floats(-10, 200, allow_nan=False), shift=st.integers(-4, 4))
def test_u4_encode_in_range(x, shift):
    q = int(ql.u4_encode(jnp.asarray(np.float32(x)), shift))
    assert 0 <= q <= 15


def test_ste_roundtrips_are_on_grid():
    w = jnp.asarray(np.linspace(-2.0, 2.0, 33, dtype=np.float32))
    wq = np.asarray(ql.ste_log2(w, 0.03125))
    grid = set()
    for c in range(-8, 8):
        grid.add(round(float(ql.log2_decode(jnp.asarray(c))) * 0.03125, 9))
    for v in wq:
        assert round(float(v), 9) in grid


def test_fold_bn_matches_direct():
    rng = np.random.default_rng(0)
    w = rng.normal(size=(3, 4, 5)).astype(np.float32)
    b = rng.normal(size=5).astype(np.float32)
    gamma = rng.uniform(0.5, 2, 5).astype(np.float32)
    beta = rng.normal(size=5).astype(np.float32)
    mean = rng.normal(size=5).astype(np.float32)
    var = rng.uniform(0.5, 2, 5).astype(np.float32)
    wf, bf = ql.fold_bn(w, b, gamma, beta, mean, var)
    x = rng.normal(size=(7, 4)).astype(np.float32)
    pre = x @ w[0] + b
    ref = gamma * (pre - mean) / np.sqrt(var + 1e-5) + beta
    got = x @ wf[0] + bf
    assert np.allclose(got, ref, atol=1e-4)
