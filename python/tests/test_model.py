"""L2 model tests: shapes, receptive field, quantized-export consistency,
integer forward vs pallas forward, and the scale-schedule invariants."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model as M
from compile import quantlib as ql

TINY = M.TCNConfig(
    name="tiny_test", in_channels=2, seq_len=64, channels=(6, 8),
    kernel_size=3, embed_dim=16, n_classes=4,
)


@pytest.fixture(scope="module")
def quantized():
    params = M.init_params(TINY, seed=1)
    rng = np.random.default_rng(0)
    x_cal = jnp.asarray(rng.uniform(0, 1, (6, TINY.seq_len, TINY.in_channels)).astype(np.float32))
    qcfg = M.calibrate(params, x_cal, TINY)
    qm = M.quantize_model(params, qcfg, TINY)
    return params, qcfg, qm


def test_param_count_formula():
    n = TINY.param_count()
    expect = (3 * 2 * 6 + 6) + (3 * 6 * 6 + 6) + (2 * 6 + 6) \
        + (3 * 6 * 8 + 8) + (3 * 8 * 8 + 8) + (6 * 8 + 8) \
        + (8 * 16 + 16) + (16 * 4 + 4)
    assert n == expect


def test_receptive_field():
    # two blocks, k=3: 1 + 2*2*1 + 2*2*2 = 13
    assert TINY.receptive_field == 13


def test_float_forward_shapes():
    params = M.init_params(TINY, seed=0)
    x = jnp.zeros((3, TINY.seq_len, TINY.in_channels))
    logits, _ = M.float_forward(params, x, TINY, train=False, with_head=True)
    assert logits.shape == (3, 4)
    emb, _ = M.float_forward(params, x, TINY, train=False, with_head=False)
    assert emb.shape == (3, TINY.embed_dim)


def test_quantized_export_invariants(quantized):
    _, _, qm = quantized
    assert len(qm.layers) == 2 * TINY.n_blocks
    for l in qm.layers:
        assert l.out_shift >= 0, "OPE shifts must be non-negative"
        assert np.abs(l.codes).max() <= 8
        assert l.bias.min() >= ql.BIAS_MIN and l.bias.max() <= ql.BIAS_MAX
        if l.res_codes is not None:
            assert l.res_out_shift >= 0
    assert qm.layers[0].dilation == 1 and qm.layers[2].dilation == 2


def test_int_forward_is_u4(quantized):
    _, _, qm = quantized
    rng = np.random.default_rng(2)
    xq = rng.integers(0, 16, (TINY.seq_len, TINY.in_channels)).astype(np.int32)
    emb = np.asarray(M.int_forward(qm, jnp.asarray(xq), with_head=False))
    assert emb.shape == (TINY.embed_dim,)
    assert emb.min() >= 0 and emb.max() <= 15


def test_pallas_and_oracle_forward_agree(quantized):
    _, _, qm = quantized
    rng = np.random.default_rng(3)
    for _ in range(3):
        xq = jnp.asarray(rng.integers(0, 16, (TINY.seq_len, TINY.in_channels)).astype(np.int32))
        a = np.asarray(M.int_forward(qm, xq, use_pallas=False, with_head=True))
        b = np.asarray(M.int_forward(qm, xq, use_pallas=True, with_head=True))
        assert (a == b).all()


def test_qat_forward_runs_and_is_finite(quantized):
    params, qcfg, _ = quantized
    x = jnp.asarray(np.random.default_rng(4).uniform(0, 1, (2, TINY.seq_len, TINY.in_channels)).astype(np.float32))
    out = M.qat_forward(params, x, TINY, qcfg, with_head=True)
    assert np.isfinite(np.asarray(out)).all()


def test_quantize_input_clamps(quantized):
    _, _, qm = quantized
    x = np.full((TINY.seq_len, TINY.in_channels), 1e9, np.float32)
    q = M.quantize_input(x, qm)
    assert q.max() == 15
    x = np.full((TINY.seq_len, TINY.in_channels), -5.0, np.float32)
    assert M.quantize_input(x, qm).max() == 0


def test_model_zoo_sane():
    for name, cfg in M.MODEL_ZOO.items():
        assert cfg.receptive_field >= cfg.seq_len // 3, name
        assert cfg.param_count() < 140_000, name  # chip capacity
