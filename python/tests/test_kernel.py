"""L1 correctness: the Pallas kernels vs the pure-jnp oracles, bit-exact,
with hypothesis sweeping shapes/dtypes/parameters — the CORE correctness
signal of the compile path."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.dilated_conv import dilated_conv
from compile.kernels.log2_matmul import log2_matmul

SETTINGS = dict(max_examples=25, deadline=None)


def rand_acts(rng, m, k):
    return rng.integers(0, 16, (m, k)).astype(np.int32)


def rand_codes(rng, *shape):
    return rng.integers(-8, 8, shape).astype(np.int32)


@settings(**SETTINGS)
@given(
    m=st.integers(1, 40),
    k=st.integers(1, 70),
    n=st.integers(1, 40),
    seed=st.integers(0, 2**31 - 1),
    tile=st.sampled_from([4, 8, 16]),
)
def test_log2_matmul_matches_ref(m, k, n, seed, tile):
    rng = np.random.default_rng(seed)
    a = rand_acts(rng, m, k)
    c = rand_codes(rng, k, n)
    want = np.asarray(ref.log2_matmul_ref(jnp.asarray(a), jnp.asarray(c)))
    got = np.asarray(log2_matmul(jnp.asarray(a), jnp.asarray(c), tile_m=tile, tile_n=tile))
    assert (got == want).all()


@settings(**SETTINGS)
@given(
    t=st.integers(1, 48),
    cin=st.integers(1, 12),
    cout=st.integers(1, 12),
    ksz=st.integers(1, 5),
    log_d=st.integers(0, 4),
    out_shift=st.integers(0, 8),
    relu=st.booleans(),
    use_res=st.booleans(),
    res_shift=st.integers(0, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_dilated_conv_matches_ref(t, cin, cout, ksz, log_d, out_shift, relu, use_res, res_shift, seed):
    rng = np.random.default_rng(seed)
    x = rand_acts(rng, t, cin)
    w = rand_codes(rng, ksz, cin, cout)
    b = rng.integers(-8192, 8192, cout).astype(np.int32)
    res = jnp.asarray(rand_acts(rng, t, cout)) if use_res else None
    kw = dict(dilation=2**log_d, relu=relu, residual=res, res_shift=res_shift)
    want = np.asarray(
        ref.dilated_conv_ref(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), out_shift, **kw)
    )
    got = np.asarray(
        dilated_conv(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), out_shift, ksz, **kw)
    )
    assert (got == want).all()


def test_matmul_saturates_like_hardware():
    # 9 slabs of maximal positive product saturate the 18-bit accumulator.
    a = np.full((1, 144), 15, np.int32)
    c = np.full((144, 1), 7, np.int32)  # decode(7) = 64
    out = np.asarray(log2_matmul(jnp.asarray(a), jnp.asarray(c)))
    assert out[0, 0] == 131071


def test_conv_is_causal():
    rng = np.random.default_rng(0)
    x = rand_acts(rng, 20, 3)
    w = rand_codes(rng, 3, 3, 4)
    b = np.zeros(4, np.int32)
    base = np.asarray(dilated_conv(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), 2, 3, dilation=2))
    x2 = x.copy()
    x2[-1] = (x2[-1] + 1) % 16
    pert = np.asarray(dilated_conv(jnp.asarray(x2), jnp.asarray(w), jnp.asarray(b), 2, 3, dilation=2))
    assert (base[:-1] == pert[:-1]).all()


def test_zero_weights_give_bias_only():
    x = np.full((4, 8), 7, np.int32)
    w = np.zeros((1, 8, 2), np.int32)
    b = np.asarray([40, -40], np.int32)
    out = np.asarray(dilated_conv(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), 2, 1))
    # (0 + 40 + 2) >> 2 = 10 (rounding shift); negative clamps to 0
    assert (out[:, 0] == 10).all()
    assert (out[:, 1] == 0).all()


@pytest.mark.parametrize("tile", [4, 16])
def test_mode_tiles_are_equivalent(tile):
    # The 4x4 and 16x16 PE-array modes are numerically identical.
    rng = np.random.default_rng(5)
    a = rand_acts(rng, 17, 33)
    c = rand_codes(rng, 33, 9)
    want = np.asarray(log2_matmul(jnp.asarray(a), jnp.asarray(c), tile_m=16, tile_n=16))
    got = np.asarray(log2_matmul(jnp.asarray(a), jnp.asarray(c), tile_m=tile, tile_n=tile))
    assert (got == want).all()
