"""L1 Pallas kernel: dilated causal conv1d layer with fused OPE.

One TCN layer of the chip: the address-generator's dilated tap gather is
expressed as a strided load schedule (im2col outside the kernel — XLA fuses
the gather into the surrounding graph), and the hot loop is the shift-add
matmul with the output-PE (bias add, residual add, arithmetic right shift,
ReLU, u4 clamp) fused into the final K-slab grid step.

VMEM per grid step (tile_t=16, tile_n=16, int32 interpret): three 1-KiB
blocks plus a 1-KiB residual block — the Pallas analogue of the chip's
single dual-port activation register file.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import quantlib as ql
from .log2_matmul import K_SLAB, _decode, _pad_to


def _conv_kernel(a_ref, c_ref, b_ref, r_ref, o_ref, *, n_k, out_shift, relu, res_shift, has_res):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.int32)
    w = _decode(c_ref[...].astype(jnp.int32))
    part = jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    acc = jnp.clip(o_ref[...] + part, ql.ACC_MIN, ql.ACC_MAX)

    @pl.when(k == n_k - 1)
    def _finalize():
        bias = jnp.clip(b_ref[...].astype(jnp.int32), ql.BIAS_MIN, ql.BIAS_MAX)
        total = acc + bias[None, :]
        if has_res:
            total = total + (r_ref[...].astype(jnp.int32) << res_shift)
        total = jnp.clip(total, ql.ACC_MIN, ql.ACC_MAX)
        if relu:
            # rounding shift: add half an LSB before the arithmetic shift
            rbias = (1 << (out_shift - 1)) if out_shift > 0 else 0
            y = jnp.right_shift(total + rbias, out_shift)
            y = jnp.clip(y, 0, ql.ACT_MAX)
        else:
            y = total
        o_ref[...] = y

    @pl.when(k != n_k - 1)
    def _store():
        o_ref[...] = acc


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "dilation", "out_shift", "relu", "res_shift", "tile_t", "tile_n"),
)
def dilated_conv(
    x,
    codes,
    bias,
    out_shift,
    kernel_size,
    dilation=1,
    relu=True,
    residual=None,
    res_shift=0,
    tile_t=16,
    tile_n=16,
):
    """Dilated causal conv1d, bit-exact vs ``ref.dilated_conv_ref``.

    ``x`` int32 [T, Cin] u4; ``codes`` int32 [K, Cin, Cout] s4 log2;
    ``bias`` int32 [Cout]. Returns int32 [T, Cout] (u4 if ``relu``, raw
    saturated logits otherwise).
    """
    t, cin = x.shape
    ksz, cin2, cout = codes.shape
    assert ksz == kernel_size and cin == cin2
    # Address-generator equivalent: dilated causal tap gather.
    pad = (kernel_size - 1) * dilation
    xp = jnp.pad(x.astype(jnp.int32), ((pad, 0), (0, 0)))
    taps = jnp.stack(
        [jax.lax.dynamic_slice_in_dim(xp, j * dilation, t, 0) for j in range(kernel_size)],
        axis=1,
    )  # [T, K, Cin]
    a = taps.reshape(t, kernel_size * cin)
    c = codes.reshape(kernel_size * cin, cout).astype(jnp.int32)

    a = _pad_to(_pad_to(a, 0, tile_t), 1, K_SLAB)
    c = _pad_to(_pad_to(c, 0, K_SLAB), 1, tile_n)
    b = _pad_to(bias.astype(jnp.int32), 0, tile_n)
    has_res = residual is not None
    if has_res:
        r = _pad_to(_pad_to(residual.astype(jnp.int32), 0, tile_t), 1, tile_n)
    else:
        r = jnp.zeros((a.shape[0], c.shape[1]), jnp.int32)

    tp, kp = a.shape
    _, np_ = c.shape
    n_k = kp // K_SLAB
    grid = (tp // tile_t, np_ // tile_n, n_k)
    out = pl.pallas_call(
        functools.partial(
            _conv_kernel,
            n_k=n_k,
            out_shift=out_shift,
            relu=relu,
            res_shift=res_shift,
            has_res=has_res,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_t, K_SLAB), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((K_SLAB, tile_n), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((tile_n,), lambda i, j, kk: (j,)),
            pl.BlockSpec((tile_t, tile_n), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((tile_t, tile_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((tp, np_), jnp.int32),
        interpret=True,
    )(a, c, b, r)
    return out[:t, :cout]
