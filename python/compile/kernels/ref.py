"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Every kernel in this package must agree bit-exactly with its oracle here;
``python/tests/test_kernel.py`` sweeps shapes with hypothesis. The oracles
also define the semantics the rust golden model mirrors.
"""

from __future__ import annotations

import jax.numpy as jnp

from .. import quantlib as ql


def log2_matmul_ref(acts, codes):
    """MatMul-free matrix multiply oracle.

    ``acts``  -- int32 [M, K], u4 range (0..15)
    ``codes`` -- int32 [K, N], s4 log2 codes (-8..7)
    returns   -- int32 [M, N], 18-bit-saturated accumulation in chip order:
                 products are summed 16 rows of K at a time (one PE-array
                 pass per cycle), the running accumulator saturating after
                 every cycle, exactly as the 18-bit output registers do.
    """
    m, k = acts.shape
    k2, n = codes.shape
    assert k == k2
    w = ql.log2_decode(codes)  # [K, N]
    acc = jnp.zeros((m, n), jnp.int32)
    for k0 in range(0, k, 16):
        part = jnp.matmul(
            acts[:, k0 : k0 + 16].astype(jnp.int32), w[k0 : k0 + 16].astype(jnp.int32)
        )
        acc = ql.sat_acc(acc + part)
    return acc


def gather_dilated_taps(x, kernel_size, dilation):
    """Causal dilated tap gather: tap j of output t reads x[t - (K-1-j)*d].

    ``x`` -- int32 [T, Cin]; returns int32 [T, K, Cin] with zero left-padding
    (the chip's address generator never reads those positions; zeros are the
    ReLU-domain neutral element).
    """
    t, cin = x.shape
    pad = (kernel_size - 1) * dilation
    xp = jnp.pad(x, ((pad, 0), (0, 0)))
    taps = [xp[j * dilation : j * dilation + t] for j in range(kernel_size)]
    return jnp.stack(taps, axis=1)


def dilated_conv_ref(
    x,
    codes,
    bias,
    out_shift,
    dilation=1,
    relu=True,
    residual=None,
    res_shift=0,
):
    """Dilated causal conv1d layer oracle, full chip datapath.

    ``x``     -- int32 [T, Cin] u4 activations
    ``codes`` -- int32 [K, Cin, Cout] s4 log2 codes
    ``bias``  -- int32 [Cout], 14-bit range
    returns   -- int32 [T, Cout]: u4 if ``relu`` else raw saturated
                 accumulator (logit readout for the final FC layer).
    """
    t, cin = x.shape
    ksz, cin2, cout = codes.shape
    assert cin == cin2
    taps = gather_dilated_taps(x, ksz, dilation)  # [T, K, Cin]
    acc = log2_matmul_ref(taps.reshape(t, ksz * cin), codes.reshape(ksz * cin, cout))
    if relu:
        return ql.ope(acc, bias, out_shift, relu=True, residual=residual, res_shift=res_shift)
    total = acc + ql.sat_bias(bias)
    if residual is not None:
        total = total + (jnp.asarray(residual, jnp.int32) << res_shift)
    return ql.sat_acc(total)


def fc_ref(x, codes, bias):
    """Final FC / prototypical layer oracle: raw logits (no ReLU/requant).

    ``x`` -- int32 [V] u4 embedding; ``codes`` -- int32 [V, N];
    ``bias`` -- int32 [N]. Returns int32 [N] saturated logits.
    """
    acc = log2_matmul_ref(x[None, :], codes)[0]
    return ql.sat_acc(acc + jnp.asarray(bias, jnp.int32))
