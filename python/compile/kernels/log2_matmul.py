"""L1 Pallas kernel: MatMul-free (shift-add) matrix multiply.

This is the chip's PE array as a Pallas kernel. Each grid step consumes one
"cycle-equivalent" slab of 16 input channels (the K axis), mirroring the
16x16 array: products are ``act << (|code|-1)`` with sign correction, summed
and accumulated into 18-bit-saturating output-stationary registers.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; structure (block shapes, schedule), not wallclock, is what we
optimize at this layer. The BlockSpec expresses the SRAM->PE schedule the
chip implements with its address generator:

  VMEM footprint per grid step (defaults, int32 in interpret mode):
    acts  tile_m x 16    = 16*16*4   = 1   KiB
    codes 16 x tile_n    = 16*16*4   = 1   KiB
    out   tile_m x tile_n= 16*16*4   = 1   KiB
  (on the chip: 16 u4 acts + 256 s4 weights + 16 i18 accumulators per cycle)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import quantlib as ql

K_SLAB = 16  # input channels consumed per PE-array pass (one cycle)


def _decode(codes):
    """s4 log2 code -> integer weight value, as shift + sign correction."""
    mag = jnp.where(codes == 0, 0, 1 << (jnp.abs(codes) - 1).astype(jnp.int32))
    return jnp.where(codes < 0, -mag, mag).astype(jnp.int32)


def _matmul_kernel(a_ref, c_ref, o_ref, *, apply_sat):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.int32)  # [tile_m, 16] u4
    w = _decode(c_ref[...].astype(jnp.int32))  # [16, tile_n]
    part = jax.lax.dot_general(
        a, w, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )
    acc = o_ref[...] + part
    if apply_sat:
        acc = jnp.clip(acc, ql.ACC_MIN, ql.ACC_MAX)
    o_ref[...] = acc


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, rem)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("tile_m", "tile_n", "saturate"))
def log2_matmul(acts, codes, tile_m=16, tile_n=16, saturate=True):
    """Pallas shift-add matmul: int32[M,K] u4 x int32[K,N] s4 codes -> int32[M,N].

    Bit-exact against ``ref.log2_matmul_ref`` (18-bit saturation applied
    after every 16-row K slab, in ascending-K order). ``tile_m``/``tile_n``
    model the PE-array mode (16 = full array, 4 = low-leakage mode).
    """
    m, k = acts.shape
    k2, n = codes.shape
    assert k == k2, f"K mismatch: {k} vs {k2}"
    a = _pad_to(_pad_to(acts.astype(jnp.int32), 0, tile_m), 1, K_SLAB)
    c = _pad_to(_pad_to(codes.astype(jnp.int32), 0, K_SLAB), 1, tile_n)
    mp, kp = a.shape
    _, np_ = c.shape
    grid = (mp // tile_m, np_ // tile_n, kp // K_SLAB)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, apply_sat=saturate),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, K_SLAB), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((K_SLAB, tile_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        interpret=True,
    )(a, c)
    return out[:m, :n]
