"""Quantization grammar of the Chameleon datapath (bit-exact spec).

This module is the single source of truth for the integer semantics of the
MatMul-free PE array; ``rust/src/quant`` mirrors it bit-exactly and the
cross-check test vectors exported by ``aot.py`` pin both sides together.

Grammar (see DESIGN.md §Quantization grammar):

* activations  -- u4 uniform, ReLU-native: ``x_q = clamp(round(x / 2^s), 0, 15)``
* weights      -- s4 log2 code ``c in [-8, 7]`` (two's-complement nibble):
                  ``value(c) = 0 if c == 0 else sgn(c) * 2**(|c| - 1)``
                  i.e. magnitudes 2^0..2^6 positive and 2^0..2^7 negative,
                  the int8-like asymmetric dynamic range the paper cites.
* product      -- activation left-shifted by the weight exponent with sign
                  correction; 15 << 7 = 1920 fits a 12-bit signed value.
* accumulator  -- 18-bit signed, saturating.
* bias         -- 14-bit signed.
* OPE          -- ``y = clamp(relu((acc + (res << res_shift) + bias) >> out_shift), 0, 15)``
                  with an arithmetic (floor) right shift, matching a
                  hardware barrel shifter.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Bit-width constants (the chip's datapath)
# ---------------------------------------------------------------------------

ACT_BITS = 4
ACT_MAX = (1 << ACT_BITS) - 1  # 15

WEIGHT_CODE_MIN = -8
WEIGHT_CODE_MAX = 7

PRODUCT_BITS = 12  # signed; 15 << 7 = 1920 < 2048

ACC_BITS = 18
ACC_MIN = -(1 << (ACC_BITS - 1))  # -131072
ACC_MAX = (1 << (ACC_BITS - 1)) - 1  # 131071

BIAS_BITS = 14
BIAS_MIN = -(1 << (BIAS_BITS - 1))  # -8192
BIAS_MAX = (1 << (BIAS_BITS - 1)) - 1  # 8191

# Decoded magnitudes representable by a log2 code (positive side).
POS_MAGNITUDES = np.array([1, 2, 4, 8, 16, 32, 64], dtype=np.int32)  # c=1..7
NEG_MAGNITUDES = np.array([1, 2, 4, 8, 16, 32, 64, 128], dtype=np.int32)  # c=-1..-8


# ---------------------------------------------------------------------------
# log2 codec
# ---------------------------------------------------------------------------

def log2_decode(code):
    """Decode s4 log2 codes to integer values.

    ``code`` is an integer array in [-8, 7]; returns int32 values in
    {0, +-1, +-2, ..., +64, -128}.
    """
    code = jnp.asarray(code, jnp.int32)
    mag = jnp.where(code == 0, 0, 1 << (jnp.abs(code) - 1).astype(jnp.int32))
    return jnp.where(code < 0, -mag, mag).astype(jnp.int32)


def log2_encode_int(value):
    """Encode integer values to the nearest representable log2 value.

    Ties between two representable magnitudes round toward the larger
    exponent iff the value is >= the geometric midpoint rounded up
    (i.e. plain nearest with ties-to-larger), matching the rust codec.
    Values beyond the dynamic range saturate (+64 / -128).
    """
    value = jnp.asarray(value, jnp.int32)
    sign_neg = value < 0
    mag = jnp.abs(value)
    # Nearest power of two: exponent e such that 2^e closest to mag.
    # For mag >= 1: e = floor(log2(mag)); round up when mag >= 1.5 * 2^e.
    # float32 log2 is exact for the magnitudes seen here (< 2^24).
    e_floor = jnp.where(
        mag > 0, jnp.floor(jnp.log2(jnp.maximum(mag, 1).astype(jnp.float32))), 0
    ).astype(jnp.int32)
    low = (1 << e_floor.astype(jnp.int32)).astype(jnp.int32)
    # round up if mag - low >= low (midpoint 1.5*low: distance to 2*low is
    # 2*low - mag; round up when mag - low >= 2*low - mag  <=> 2*mag >= 3*low)
    e = jnp.where(2 * mag >= 3 * low, e_floor + 1, e_floor)
    e_pos = jnp.clip(e, 0, 6)
    e_neg = jnp.clip(e, 0, 7)
    code = jnp.where(
        mag == 0,
        0,
        jnp.where(sign_neg, -(e_neg + 1), e_pos + 1),
    )
    return code.astype(jnp.int32)


def log2_encode_float(value, scale=1.0):
    """Quantize real weights to log2 codes: ``encode(round-to-grid(v/scale))``.

    Quantizes ``value / scale`` to the nearest representable log2 point
    (including 0), by true nearest-value comparison in the real domain —
    used by QAT, where the grid matters more than integer rounding.
    """
    v = jnp.asarray(value, jnp.float32) / scale
    # Candidate representable values.
    cands = np.concatenate(
        [np.array([0.0]), POS_MAGNITUDES.astype(np.float64), -NEG_MAGNITUDES.astype(np.float64)]
    )
    codes = np.concatenate(
        [np.array([0]), np.arange(1, 8), -np.arange(1, 9)]
    ).astype(np.int32)
    d = jnp.abs(v[..., None] - cands[None, :])
    idx = jnp.argmin(d, axis=-1)
    return jnp.asarray(codes)[idx].astype(jnp.int32)


# ---------------------------------------------------------------------------
# u4 activation codec
# ---------------------------------------------------------------------------

def u4_encode(x, shift):
    """``clamp(round(x / 2^shift), 0, 15)`` — power-of-two scale."""
    q = jnp.round(jnp.asarray(x, jnp.float32) / (2.0 ** shift))
    return jnp.clip(q, 0, ACT_MAX).astype(jnp.int32)


def u4_decode(q, shift):
    return jnp.asarray(q, jnp.float32) * (2.0 ** shift)


# ---------------------------------------------------------------------------
# Integer datapath primitives
# ---------------------------------------------------------------------------

def shift_product(act, code):
    """u4 activation x log2 weight -> signed product (12-bit range).

    Exactly ``act * log2_decode(code)`` — on the chip this is a left shift
    by ``|code|-1`` plus sign correction.
    """
    return (jnp.asarray(act, jnp.int32) * log2_decode(code)).astype(jnp.int32)


def sat_acc(x):
    """Saturate to the 18-bit signed accumulator range."""
    return jnp.clip(jnp.asarray(x, jnp.int32), ACC_MIN, ACC_MAX)


def sat_bias(x):
    """Saturate to the 14-bit signed bias range."""
    return jnp.clip(jnp.asarray(x, jnp.int32), BIAS_MIN, BIAS_MAX)


def arithmetic_shift_right(x, shift):
    """Floor division by 2^shift (arithmetic shift, exact for negatives)."""
    x = jnp.asarray(x, jnp.int32)
    return jnp.right_shift(x, jnp.asarray(shift, jnp.int32))


def rounding_shift_right(x, shift):
    """Round-half-up shift: ``(x + 2^(s-1)) >> s`` — the OPE's rounding
    adder. Matches the round() semantics QAT trains with (up to the
    half-up vs half-even difference at exact midpoints) instead of a plain
    floor, which would lose 0.5 LSB per layer and compound over depth.
    """
    x = jnp.asarray(x, jnp.int32)
    s = jnp.asarray(shift, jnp.int32)
    bias = jnp.where(s > 0, 1 << jnp.maximum(s - 1, 0), 0)
    return jnp.right_shift(x + bias, s)


def ope(acc, bias, out_shift, relu=True, residual=None, res_shift=0):
    """Output-PE: residual add, bias add, shift, ReLU, clamp to u4.

    ``acc`` int32 (18-bit range), ``bias`` int32 (14-bit range),
    ``residual`` u4 (pre-rescaled with ``res_shift``). Returns u4 int32.
    """
    acc = jnp.asarray(acc, jnp.int32)
    total = acc + sat_bias(bias)
    if residual is not None:
        total = total + (jnp.asarray(residual, jnp.int32) << res_shift)
    total = sat_acc(total)
    if relu:
        y = rounding_shift_right(total, out_shift)
        y = jnp.maximum(y, 0)
        y = jnp.minimum(y, ACT_MAX)
    else:
        y = total
    return y.astype(jnp.int32)


# ---------------------------------------------------------------------------
# Straight-through estimators for QAT
# ---------------------------------------------------------------------------

@jax.custom_vjp
def ste_u4(x, shift):
    """Fake-quantize activations to the u4 grid (forward), identity grad."""
    q = jnp.clip(jnp.round(x / (2.0 ** shift)), 0.0, float(ACT_MAX))
    return q * (2.0 ** shift)


def _ste_u4_fwd(x, shift):
    return ste_u4(x, shift), (x, shift)


def _ste_u4_bwd(res, g):
    x, shift = res
    lo, hi = 0.0, ACT_MAX * (2.0 ** shift)
    mask = ((x >= lo) & (x <= hi)).astype(g.dtype)
    return (g * mask, None)


ste_u4.defvjp(_ste_u4_fwd, _ste_u4_bwd)


@jax.custom_vjp
def ste_log2(w, scale):
    """Fake-quantize weights to the log2 grid (forward), identity grad."""
    code = log2_encode_float(w, scale)
    return log2_decode(code).astype(jnp.float32) * scale


def _ste_log2_fwd(w, scale):
    return ste_log2(w, scale), (w, scale)


def _ste_log2_bwd(res, g):
    w, scale = res
    lo, hi = -128.0 * scale, 64.0 * scale
    mask = ((w >= lo) & (w <= hi)).astype(g.dtype)
    return (g * mask, None)


ste_log2.defvjp(_ste_log2_fwd, _ste_log2_bwd)


# ---------------------------------------------------------------------------
# Scale selection + BN folding
# ---------------------------------------------------------------------------

def choose_weight_scale(w):
    """Per-tensor power-of-two scale so max |w| maps near the log2 grid top."""
    m = float(np.max(np.abs(np.asarray(w)))) + 1e-12
    # Map the max magnitude to ~48 (between 2^5 and 2^6) to limit saturation.
    s = 2.0 ** np.ceil(np.log2(m / 48.0))
    return float(s)


def choose_act_shift(x_max):
    """Power-of-two shift so x_max maps near the top of the u4 grid."""
    s = int(np.ceil(np.log2((float(x_max) + 1e-12) / ACT_MAX)))
    return max(s, -8)


def fold_bn(w, b, gamma, beta, mean, var, eps=1e-5):
    """Fold batch-norm into the preceding conv/FC weights and bias.

    y = gamma * (conv(x, w) + b - mean) / sqrt(var + eps) + beta
      = conv(x, w * g') + (b - mean) * g' + beta,  g' = gamma / sqrt(var+eps)
    ``w`` has the output-channel axis LAST (…, Cout).
    """
    g = gamma / np.sqrt(var + eps)
    w_f = np.asarray(w) * g  # broadcast over trailing Cout axis
    b_f = (np.asarray(b) - mean) * g + beta
    return w_f, b_f
