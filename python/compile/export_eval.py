"""Export quantized evaluation datasets for the rust benches/examples.

The rust side has no python at run time, so the synthetic evaluation pools
(meta-test Omniglot classes, KWS test utterances) are exported once as u4
sequences, hex-packed (one hex digit per u4 activation, row-major [T][C])
to keep the JSON compact.

Outputs:
    artifacts/eval_omniglot.json  -- meta-TEST classes only (disjoint from
                                     the meta-training pool, Vinyals-style)
    artifacts/eval_kws_mfcc.json  -- 12-class test split, MFCC view
    artifacts/eval_kws_raw.json   -- 12-class test split, raw view
"""

from __future__ import annotations

import argparse
import json
import os

import numpy as np

from . import datasets as D
from . import train as T

HEX = np.asarray(list("0123456789abcdef"))

# Meta-test classes: disjoint from train.OMNIGLOT_TRAIN_CLASSES (0..300).
EVAL_OMNIGLOT_FIRST = 300
EVAL_OMNIGLOT_COUNT = 260  # supports 250-way CL + query margin


def pack_u4(seq_q: np.ndarray) -> str:
    """u4 int array -> hex string, row-major."""
    flat = np.asarray(seq_q, np.int32).reshape(-1)
    assert ((flat >= 0) & (flat <= 15)).all()
    return "".join(HEX[flat])


def model_in_shift(artifacts: str, name: str) -> int:
    with open(os.path.join(artifacts, f"{name}.model.json")) as f:
        return int(json.load(f)["in_shift"])


def quant_u4(x: np.ndarray, shift: int) -> np.ndarray:
    q = np.round(np.asarray(x, np.float64) / (2.0**shift))
    return np.clip(q, 0, 15).astype(np.int32)


def export_omniglot(artifacts: str, samples_per_class: int = 20):
    shift = model_in_shift(artifacts, "omniglot_fsl")
    n_total = EVAL_OMNIGLOT_FIRST + EVAL_OMNIGLOT_COUNT
    ds = D.SyntheticOmniglot(n_total)
    data = []
    for c in range(EVAL_OMNIGLOT_FIRST, n_total):
        for s in range(samples_per_class):
            data.append(pack_u4(quant_u4(ds.sample(c, s), shift)))
    blob = {
        "name": "omniglot_eval",
        "seq_len": 784,
        "in_channels": 1,
        "classes": EVAL_OMNIGLOT_COUNT,
        "samples_per_class": samples_per_class,
        "in_shift": shift,
        "first_class_id": EVAL_OMNIGLOT_FIRST,
        "data": data,
    }
    path = os.path.join(artifacts, "eval_omniglot.json")
    with open(path, "w") as f:
        json.dump(blob, f)
    print(f"[export] {path}: {len(data)} sequences")


def export_kws(artifacts: str, view: str, samples_per_class: int = 20, base: int = 1000):
    name = f"kws_{view}"
    shift = model_in_shift(artifacts, name)
    ds = D.SyntheticSpeechCommands()
    cfg = ds.cfg
    data = []
    for c in range(D.N_CLASSES):
        for s in range(samples_per_class):
            x = ds.sample(c, base + s, view)
            data.append(pack_u4(quant_u4(x, shift)))
    blob = {
        "name": f"{name}_eval",
        "seq_len": cfg.n_frames if view == "mfcc" else cfg.n_samples,
        "in_channels": cfg.n_mfcc if view == "mfcc" else 1,
        "classes": D.N_CLASSES,
        "class_names": D.CLASSES,
        "samples_per_class": samples_per_class,
        "in_shift": shift,
        "data": data,
    }
    path = os.path.join(artifacts, f"eval_{name}.json")
    with open(path, "w") as f:
        json.dump(blob, f)
    print(f"[export] {path}: {len(data)} sequences")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    out = args.out if os.path.isabs(args.out) else os.path.abspath(args.out)
    export_omniglot(out)
    export_kws(out, "mfcc")
    export_kws(out, "raw")


if __name__ == "__main__":
    main()
