"""Synthetic dataset generators (substitutes for Omniglot and GSCv2).

The paper evaluates on Omniglot (handwritten characters, 1 623 classes) and
Google Speech Commands v2 (105 829 utterances @ 16 kHz). Neither dataset is
available in this environment; per DESIGN.md we substitute procedurally
generated equivalents that exercise the identical code paths:

* ``SyntheticOmniglot`` -- each class is a random stroke-based glyph
  (2-4 quadratic Bezier strokes), rasterised to 28x28 and flattened pixelwise
  to a 784-step 1-channel sequence ("sequential Omniglot", paper Fig. 14).
  Per-sample jitter (affine warp + control-point noise + stroke thickness)
  emulates different writers. 20 samples/class like the original.

* ``SyntheticSpeechCommands`` -- 12 classes mirroring the GSCv2 12-way setup:
  10 "keyword" classes, each a formant-like harmonic word with a
  class-specific pitch/formant contour, plus ``unknown`` (random held-out
  signatures) and ``silence`` (noise). Two views: raw audio (length
  configurable, default 2 048 steps standing in for 16 000 @ 16 kHz) and an
  MFCC-like 28-D x 63-step feature map computed with a numpy mel-ish
  filterbank front-end (window 32 ms / hop 16 ms scaled to the sample rate).

Everything is seeded and pure-numpy so python and rust can regenerate
identical data from the same seed-derived parameters if needed.
"""

from __future__ import annotations

import dataclasses

import numpy as np

# ---------------------------------------------------------------------------
# Sequential Omniglot substitute
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OmniglotConfig:
    image_size: int = 28
    samples_per_class: int = 20
    strokes_min: int = 2
    strokes_max: int = 4
    jitter_rot: float = 0.18  # radians, per-sample rotation jitter
    jitter_scale: float = 0.12
    jitter_shift: float = 1.5  # pixels
    point_noise: float = 0.035  # control-point jitter (fraction of canvas)
    seed: int = 2025


class SyntheticOmniglot:
    """Procedural stroke-glyph classes, flattened to 784-step sequences."""

    def __init__(self, n_classes: int, cfg: OmniglotConfig = OmniglotConfig()):
        self.cfg = cfg
        self.n_classes = n_classes
        rng = np.random.default_rng(cfg.seed)
        self._class_strokes = [self._sample_class(rng) for _ in range(n_classes)]
        self._cache = {}  # (class_id, sample_id) -> rendered sequence

    def _sample_class(self, rng):
        n_strokes = int(rng.integers(self.cfg.strokes_min, self.cfg.strokes_max + 1))
        strokes = []
        for _ in range(n_strokes):
            # Quadratic Bezier in normalized [0.1, 0.9]^2 canvas coordinates.
            pts = rng.uniform(0.12, 0.88, size=(3, 2))
            width = rng.uniform(0.5, 1.4)
            strokes.append((pts, width))
        return strokes

    def render(self, class_id: int, sample_rng) -> np.ndarray:
        """Render one jittered sample -> float image [S, S] in [0, 1]."""
        cfg = self.cfg
        s = cfg.image_size
        img = np.zeros((s, s), np.float32)
        rot = sample_rng.normal(0.0, cfg.jitter_rot)
        scale = 1.0 + sample_rng.normal(0.0, cfg.jitter_scale)
        shift = sample_rng.normal(0.0, cfg.jitter_shift, size=2)
        cos, sin = np.cos(rot), np.sin(rot)
        for pts, width in self._class_strokes[class_id]:
            p = pts + sample_rng.normal(0.0, cfg.point_noise, size=pts.shape)
            # Affine warp about the canvas centre.
            c = p - 0.5
            c = np.stack([cos * c[:, 0] - sin * c[:, 1], sin * c[:, 0] + cos * c[:, 1]], 1)
            p = (c * scale + 0.5) * (s - 1) + shift
            w = width * (1.0 + sample_rng.normal(0.0, 0.15))
            self._draw_bezier(img, p, max(w, 0.35))
        return np.clip(img, 0.0, 1.0)

    @staticmethod
    def _draw_bezier(img, pts, width):
        s = img.shape[0]
        t = np.linspace(0.0, 1.0, 64)[:, None]
        curve = ((1 - t) ** 2) * pts[0] + 2 * (1 - t) * t * pts[1] + (t**2) * pts[2]
        yy, xx = np.mgrid[0:s, 0:s]
        for cx, cy in curve:
            d2 = (xx - cx) ** 2 + (yy - cy) ** 2
            img += np.exp(-d2 / (2.0 * width**2)).astype(np.float32) * 0.6
        np.clip(img, 0.0, 1.0, out=img)

    def sample(self, class_id: int, sample_id: int) -> np.ndarray:
        """Deterministic sample: sequence [784, 1] float in [0, 1] (memoized)."""
        key = (class_id, sample_id)
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        rng = np.random.default_rng(
            (self.cfg.seed * 1_000_003 + class_id) * 1_009 + sample_id
        )
        seq = self.render(class_id, rng).reshape(-1, 1)
        self._cache[key] = seq
        return seq

    def episode(self, rng, n_way: int, k_shot: int, n_query: int, class_pool=None):
        """Sample an FSL episode: (support [N,k,T,1], query [N,q,T,1])."""
        pool = np.arange(self.n_classes) if class_pool is None else np.asarray(class_pool)
        classes = rng.choice(pool, size=n_way, replace=False)
        sup, qry = [], []
        for c in classes:
            ids = rng.choice(self.cfg.samples_per_class, size=k_shot + n_query, replace=False)
            sup.append([self.sample(int(c), int(i)) for i in ids[:k_shot]])
            qry.append([self.sample(int(c), int(i)) for i in ids[k_shot:]])
        return np.asarray(sup, np.float32), np.asarray(qry, np.float32), classes


# ---------------------------------------------------------------------------
# Synthetic speech commands (GSCv2 substitute)
# ---------------------------------------------------------------------------

KEYWORDS = ["yes", "no", "up", "down", "left", "right", "on", "off", "stop", "go"]
CLASSES = KEYWORDS + ["unknown", "silence"]
N_CLASSES = len(CLASSES)  # 12


@dataclasses.dataclass(frozen=True)
class SpeechConfig:
    sample_rate: int = 2048  # stand-in for 16 kHz; 16000 supported
    duration: float = 1.0  # seconds
    n_mfcc: int = 28
    win_ms: float = 32.0
    hop_ms: float = 16.0
    noise_prob: float = 0.15
    noise_level: float = 0.08
    n_unknown_words: int = 8
    seed: int = 7

    @property
    def n_samples(self) -> int:
        return int(self.sample_rate * self.duration)

    @property
    def n_frames(self) -> int:
        # ceil((T - win)/hop) + 1: the final (partial) frame is zero-padded,
        # giving the KWS-standard 63 frames at the default configuration.
        win = int(self.sample_rate * self.win_ms / 1000.0)
        hop = int(self.sample_rate * self.hop_ms / 1000.0)
        return max(-(-(self.n_samples - win) // hop) + 1, 1)


class SyntheticSpeechCommands:
    """Formant-like parametric 'words' with speaker variation + noise."""

    def __init__(self, cfg: SpeechConfig = SpeechConfig()):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # Class-specific word signatures: 2 formant tracks (start/end freq as
        # a fraction of Nyquist) + a syllable amplitude envelope shape.
        self._signatures = {}
        for i, name in enumerate(KEYWORDS):
            self._signatures[name] = self._sample_word(rng)
        self._unknown_sigs = [self._sample_word(rng) for _ in range(cfg.n_unknown_words)]

    @staticmethod
    def _sample_word(rng):
        n_formants = int(rng.integers(2, 4))
        formants = []
        for _ in range(n_formants):
            f0 = rng.uniform(0.04, 0.32)
            f1 = np.clip(f0 * rng.uniform(0.6, 1.7), 0.03, 0.40)
            amp = rng.uniform(0.4, 1.0)
            formants.append((f0, f1, amp))
        n_syll = int(rng.integers(1, 3))
        syll = rng.uniform(0.25, 0.95, size=n_syll)
        return formants, syll

    def _synth(self, sig, rng) -> np.ndarray:
        cfg = self.cfg
        n = cfg.n_samples
        t = np.arange(n) / cfg.sample_rate
        formants, syll = sig
        # Speaker variation: global pitch shift + per-formant detune + tempo.
        pitch = rng.uniform(0.85, 1.18)
        audio = np.zeros(n, np.float64)
        # Syllable envelope.
        env = np.zeros(n)
        n_s = len(syll)
        for si, amp in enumerate(syll):
            c = (si + 0.5) / n_s * cfg.duration * rng.uniform(0.9, 1.1)
            w = cfg.duration / (2.5 * n_s) * rng.uniform(0.8, 1.25)
            env += amp * np.exp(-((t - c) ** 2) / (2 * w**2))
        for f0, f1, amp in formants:
            det = rng.uniform(0.94, 1.06)
            f_track = (f0 + (f1 - f0) * (t / cfg.duration)) * pitch * det
            f_hz = f_track * (cfg.sample_rate / 2.0)
            phase = 2 * np.pi * np.cumsum(f_hz) / cfg.sample_rate
            audio += amp * np.sin(phase + rng.uniform(0, 2 * np.pi))
        audio *= env
        # Time shift augmentation (up to 100 ms, as in the paper).
        shift = int(rng.uniform(-0.1, 0.1) * cfg.sample_rate)
        audio = np.roll(audio, shift)
        if rng.uniform() < cfg.noise_prob:
            audio = audio + rng.normal(0.0, cfg.noise_level, n)
        peak = np.max(np.abs(audio)) + 1e-9
        return (audio / peak * 0.9).astype(np.float32)

    def raw(self, class_id: int, sample_rng) -> np.ndarray:
        """One raw-audio sample -> float32 [n_samples, 1] in [-1, 1]."""
        cfg = self.cfg
        name = CLASSES[class_id]
        if name == "silence":
            level = sample_rng.uniform(0.01, 0.2)
            audio = sample_rng.normal(0.0, level, cfg.n_samples).astype(np.float32)
            return audio[:, None]
        if name == "unknown":
            sig = self._unknown_sigs[int(sample_rng.integers(len(self._unknown_sigs)))]
        else:
            sig = self._signatures[name]
        return self._synth(sig, sample_rng)[:, None]

    def mfcc(self, audio: np.ndarray) -> np.ndarray:
        """MFCC-like features: log-mel filterbank + DCT -> [n_frames, n_mfcc]."""
        cfg = self.cfg
        x = audio.reshape(-1)
        win = int(cfg.sample_rate * cfg.win_ms / 1000.0)
        hop = int(cfg.sample_rate * cfg.hop_ms / 1000.0)
        n_frames = cfg.n_frames
        window = np.hanning(win)
        n_fft_bins = win // 2 + 1
        mel = _mel_filterbank(n_fft_bins, cfg.n_mfcc + 2, cfg.sample_rate)
        feats = np.zeros((n_frames, cfg.n_mfcc), np.float32)
        dct = _dct_matrix(cfg.n_mfcc + 2, cfg.n_mfcc)
        for f in range(n_frames):
            fr = x[f * hop : f * hop + win]
            if fr.shape[0] < win:
                fr = np.pad(fr, (0, win - fr.shape[0]))
            spec = np.abs(np.fft.rfft(fr * window)) ** 2
            melspec = np.log(mel @ spec + 1e-6)
            feats[f] = (dct @ melspec).astype(np.float32)
        return feats

    def sample(self, class_id: int, sample_id: int, view: str = "raw") -> np.ndarray:
        rng = np.random.default_rng((self.cfg.seed * 999_983 + class_id) * 10_007 + sample_id)
        audio = self.raw(class_id, rng)
        if view == "raw":
            return audio
        if view == "mfcc":
            return self.mfcc(audio)
        raise ValueError(f"unknown view {view!r}")

    def batch(self, rng, batch_size: int, view: str = "raw"):
        """Random labelled batch -> (x [B, T, C], y [B])."""
        ys = rng.integers(0, N_CLASSES, size=batch_size)
        xs = [self.sample(int(y), int(rng.integers(0, 2**31 - 1)), view) for y in ys]
        return np.stack(xs).astype(np.float32), ys.astype(np.int32)

    def fixed_split(self, n_per_class: int, view: str, base: int = 0):
        """Deterministic eval split: (x, y) with n_per_class samples/class."""
        xs, ys = [], []
        for c in range(N_CLASSES):
            for i in range(n_per_class):
                xs.append(self.sample(c, base + i, view))
                ys.append(c)
        return np.stack(xs).astype(np.float32), np.asarray(ys, np.int32)


def _mel_filterbank(n_bins: int, n_mels: int, sample_rate: int) -> np.ndarray:
    def hz_to_mel(f):
        return 2595.0 * np.log10(1.0 + f / 700.0)

    def mel_to_hz(m):
        return 700.0 * (10.0 ** (m / 2595.0) - 1.0)

    f_max = sample_rate / 2.0
    mels = np.linspace(hz_to_mel(0.0), hz_to_mel(f_max), n_mels + 2)
    freqs = mel_to_hz(mels)
    bins = np.floor((n_bins - 1) * freqs / f_max).astype(int)
    fb = np.zeros((n_mels, n_bins))
    for m in range(1, n_mels + 1):
        lo, c, hi = bins[m - 1], bins[m], bins[m + 1]
        if c == lo:
            c = min(lo + 1, n_bins - 1)
        if hi <= c:
            hi = min(c + 1, n_bins - 1)
        for k in range(lo, c):
            fb[m - 1, k] = (k - lo) / max(c - lo, 1)
        for k in range(c, hi):
            fb[m - 1, k] = (hi - k) / max(hi - c, 1)
    return fb


def _dct_matrix(n_in: int, n_out: int) -> np.ndarray:
    k = np.arange(n_out)[:, None]
    n = np.arange(n_in)[None, :]
    return np.cos(np.pi * k * (2 * n + 1) / (2 * n_in)) * np.sqrt(2.0 / n_in)
