"""L2: the TCN embedder (paper Fig. 7) in JAX — float, QAT, and integer forms.

Three forwards over one parameter set:

* ``float_forward``   -- FP32 training graph (BN + ReLU + residual blocks).
* ``qat_forward``     -- fake-quantized graph (STE log2 weights / u4 acts)
                         used for quantization-aware finetuning.
* ``int_forward``     -- bit-exact integer graph over a ``QuantizedModel``
                         (what the chip executes); backed either by the
                         pure-jnp oracles or the Pallas kernels — this is
                         the graph ``aot.py`` lowers to HLO.

Network structure (paper Fig. 7(a)): stacked residual blocks, each holding
two causal dilated conv1d layers (dilation doubles per block) with BN+ReLU,
plus an identity or 1x1-conv residual; after the last block the final
timestep feeds an FC embedding layer, optionally followed by a classifier /
prototypical FC head.

Scale bookkeeping (DESIGN.md §Quantization grammar): a tensor with u4 codes
``q`` and shift ``e`` represents ``q * 2^e``; weight codes with po2 scale
``2^g`` make the accumulator scale ``2^(e_in+g)``; biases are stored at
accumulator scale; the OPE right-shift is ``e_out - e_in - g`` (forced >= 0
by bumping ``e_out`` when calibration asks for a finer grid than the
accumulator provides). The residual enters the conv2 OPE rescaled by the
*signed* shift ``e_blk - (e_in2 + g2)``; negative values are applied as a
floor right-shift on the u4 residual before the merge — identical semantics
in the oracle, the Pallas kernel, and the rust golden model.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import quantlib as ql
from .kernels import ref as kref


@dataclasses.dataclass(frozen=True)
class TCNConfig:
    """Architecture of one Chameleon-deployable TCN."""

    name: str
    in_channels: int
    seq_len: int
    channels: tuple  # output channels per residual block; dilation = 2**i
    kernel_size: int
    embed_dim: int
    n_classes: Optional[int] = None  # fixed head (KWS); None = PN embedder

    @property
    def n_blocks(self) -> int:
        return len(self.channels)

    @property
    def dilations(self) -> tuple:
        return tuple(2**i for i in range(self.n_blocks))

    @property
    def receptive_field(self) -> int:
        # R = 1 + sum over layers of (k-1) * d  (two layers per block)
        return 1 + sum(2 * (self.kernel_size - 1) * d for d in self.dilations)

    def param_count(self) -> int:
        n, cin = 0, self.in_channels
        for c in self.channels:
            n += self.kernel_size * cin * c + c  # conv1 + bias
            n += self.kernel_size * c * c + c  # conv2 + bias
            if cin != c:
                n += cin * c + c  # 1x1 residual
            cin = c
        n += cin * self.embed_dim + self.embed_dim
        if self.n_classes:
            n += self.embed_dim * self.n_classes + self.n_classes
        return n


# Standard model zoo (the paper's three deployments, scaled per DESIGN.md).
OMNIGLOT_CFG = TCNConfig(
    name="omniglot_fsl", in_channels=1, seq_len=784,
    channels=(24, 24, 24, 24, 32, 32), kernel_size=7, embed_dim=64,
)
KWS_MFCC_CFG = TCNConfig(
    name="kws_mfcc", in_channels=28, seq_len=63, channels=(20, 20, 24, 24),
    kernel_size=5, embed_dim=32, n_classes=12,
)
KWS_RAW_CFG = TCNConfig(
    name="kws_raw", in_channels=1, seq_len=2048,
    channels=(16, 16, 16, 24, 24, 32, 32, 32), kernel_size=5, embed_dim=32,
    n_classes=12,
)

MODEL_ZOO = {c.name: c for c in (OMNIGLOT_CFG, KWS_MFCC_CFG, KWS_RAW_CFG)}


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _he(rng, shape, fan_in):
    return (rng.standard_normal(shape) * np.sqrt(2.0 / fan_in)).astype(np.float32)


def init_params(cfg: TCNConfig, seed: int = 0):
    """He-initialised float parameters (paper §IV-A initialisation)."""
    rng = np.random.default_rng(seed)
    blocks = []
    cin = cfg.in_channels
    for c in cfg.channels:
        def conv(ci, co):
            return {
                "w": _he(rng, (cfg.kernel_size, ci, co), cfg.kernel_size * ci),
                "b": np.zeros(co, np.float32),
                "bn": {
                    "gamma": np.ones(co, np.float32),
                    "beta": np.zeros(co, np.float32),
                    "mean": np.zeros(co, np.float32),
                    "var": np.ones(co, np.float32),
                },
            }

        block = {"conv1": conv(cin, c), "conv2": conv(c, c)}
        if cin != c:
            block["res"] = {"w": _he(rng, (1, cin, c), cin), "b": np.zeros(c, np.float32)}
        blocks.append(block)
        cin = c
    params = {
        "blocks": blocks,
        "embed": {
            "w": _he(rng, (cin, cfg.embed_dim), cin),
            "b": np.zeros(cfg.embed_dim, np.float32),
        },
    }
    if cfg.n_classes:
        params["head"] = {
            "w": _he(rng, (cfg.embed_dim, cfg.n_classes), cfg.embed_dim),
            "b": np.zeros(cfg.n_classes, np.float32),
        }
    return jax.tree_util.tree_map(jnp.asarray, params)


# ---------------------------------------------------------------------------
# Float forward (training graph)
# ---------------------------------------------------------------------------

def _causal_conv(x, w, dilation):
    """x [B, T, C] * w [K, Cin, Cout], causal, dilated."""
    k = w.shape[0]
    pad = (k - 1) * dilation
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1,), padding=[(pad, 0)], rhs_dilation=(dilation,),
        dimension_numbers=("NWC", "WIO", "NWC"),
    )


def _bn(x, bn, train, momentum=0.9):
    if train:
        mean = jnp.mean(x, axis=(0, 1))
        var = jnp.var(x, axis=(0, 1))
        new = {
            "gamma": bn["gamma"], "beta": bn["beta"],
            "mean": momentum * bn["mean"] + (1 - momentum) * mean,
            "var": momentum * bn["var"] + (1 - momentum) * var,
        }
    else:
        mean, var, new = bn["mean"], bn["var"], bn
    y = (x - mean) / jnp.sqrt(var + 1e-5) * bn["gamma"] + bn["beta"]
    return y, new


def float_forward(params, x, cfg: TCNConfig, train: bool = False, with_head: bool = True):
    """FP32 forward. x [B, T, Cin] -> (embedding [B, V] or logits, new_params)."""
    new_blocks = []
    h = x
    for bi, block in enumerate(params["blocks"]):
        d = 2**bi
        res = h
        y, bn1 = _bn(
            _causal_conv(h, block["conv1"]["w"], d) + block["conv1"]["b"],
            block["conv1"]["bn"], train,
        )
        y = jax.nn.relu(y)
        y, bn2 = _bn(
            _causal_conv(y, block["conv2"]["w"], d) + block["conv2"]["b"],
            block["conv2"]["bn"], train,
        )
        if "res" in block:
            # The chip stores the 1x1-residual output as u4 (unsigned), so
            # the residual path is ReLU'd — mirrored here for consistency
            # across the float / QAT / integer graphs.
            res = jax.nn.relu(_causal_conv(res, block["res"]["w"], 1) + block["res"]["b"])
        h = jax.nn.relu(y + res)
        nb = dict(block)
        nb["conv1"] = dict(block["conv1"], bn=bn1)
        nb["conv2"] = dict(block["conv2"], bn=bn2)
        new_blocks.append(nb)
    last = h[:, -1, :]
    emb = jax.nn.relu(last @ params["embed"]["w"] + params["embed"]["b"])
    new_params = dict(params, blocks=new_blocks)
    if with_head and "head" in params:
        return emb @ params["head"]["w"] + params["head"]["b"], new_params
    return emb, new_params


# ---------------------------------------------------------------------------
# QAT forward (fake-quantized training graph)
# ---------------------------------------------------------------------------

def _fake_u4(x, shift):
    return ql.ste_u4(x, shift)


def qat_forward(params, x, cfg: TCNConfig, qcfg, with_head: bool = True):
    """Fake-quantized forward using calibrated scales ``qcfg``.

    BN is folded (eval statistics) so the graph matches the chip's datapath,
    with STE quantizers on weights and activations.
    """
    h = _fake_u4(x, qcfg["in_shift"])
    for bi, block in enumerate(params["blocks"]):
        d = 2**bi
        lq = qcfg["blocks"][bi]
        res = h
        w1, b1 = _folded(block["conv1"])
        y = _causal_conv(h, ql.ste_log2(w1, lq["conv1"]["w_scale"]), d) + b1
        y = _fake_u4(jax.nn.relu(y), lq["conv1"]["act_shift"])
        w2, b2 = _folded(block["conv2"])
        y = _causal_conv(y, ql.ste_log2(w2, lq["conv2"]["w_scale"]), d) + b2
        if "res" in block:
            res = _causal_conv(
                res, ql.ste_log2(block["res"]["w"], lq["res"]["w_scale"]), 1
            ) + block["res"]["b"]
            res = _fake_u4(jax.nn.relu(res), qcfg["in_shift"] if bi == 0 else qcfg["blocks"][bi - 1]["out_shift_act"])
        h = _fake_u4(jax.nn.relu(y + res), lq["out_shift_act"])
    last = h[:, -1, :]
    emb = jax.nn.relu(
        last @ ql.ste_log2(params["embed"]["w"], qcfg["embed"]["w_scale"])
        + params["embed"]["b"]
    )
    emb = _fake_u4(emb, qcfg["embed"]["act_shift"])
    if with_head and "head" in params:
        return emb @ ql.ste_log2(params["head"]["w"], qcfg["head"]["w_scale"]) + params["head"]["b"]
    return emb


def _folded(conv):
    bn = conv["bn"]
    g = bn["gamma"] / jnp.sqrt(bn["var"] + 1e-5)
    return conv["w"] * g, (conv["b"] - bn["mean"]) * g + bn["beta"]


# ---------------------------------------------------------------------------
# Calibration
# ---------------------------------------------------------------------------

def calibrate(params, x_cal, cfg: TCNConfig):
    """Run the float graph on calibration data; pick po2 scales per tensor."""

    def act_shift(t, pct=99.7):
        m = float(np.percentile(np.asarray(t), pct)) + 1e-9
        return ql.choose_act_shift(m)

    h = x_cal
    in_shift = act_shift(h, pct=100.0)
    h = jnp.round(h / 2.0**in_shift).clip(0, 15) * 2.0**in_shift
    blocks = []
    for bi, block in enumerate(params["blocks"]):
        d = 2**bi
        w1, b1 = _folded(block["conv1"])
        res = h
        y = jax.nn.relu(_causal_conv(h, w1, d) + b1)
        s1 = act_shift(y)
        y = jnp.round(y / 2.0**s1).clip(0, 15) * 2.0**s1
        w2, b2 = _folded(block["conv2"])
        z = _causal_conv(y, w2, d) + b2
        lq = {
            "conv1": {"w_scale": ql.choose_weight_scale(w1), "act_shift": s1},
            "conv2": {"w_scale": ql.choose_weight_scale(w2)},
        }
        if "res" in block:
            res = jax.nn.relu(_causal_conv(res, block["res"]["w"], 1) + block["res"]["b"])
            lq["res"] = {"w_scale": ql.choose_weight_scale(block["res"]["w"])}
        h = jax.nn.relu(z + res)
        so = act_shift(h)
        h = jnp.round(h / 2.0**so).clip(0, 15) * 2.0**so
        lq["out_shift_act"] = so
        blocks.append(lq)
    last = h[:, -1, :]
    emb = jax.nn.relu(last @ params["embed"]["w"] + params["embed"]["b"])
    qcfg = {
        "in_shift": in_shift,
        "blocks": blocks,
        "embed": {
            "w_scale": ql.choose_weight_scale(params["embed"]["w"]),
            "act_shift": act_shift(emb),
        },
    }
    if "head" in params:
        qcfg["head"] = {"w_scale": ql.choose_weight_scale(params["head"]["w"])}
    return qcfg


# ---------------------------------------------------------------------------
# Quantized export
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class QLayer:
    """One integer conv/FC layer as the chip sees it."""

    codes: np.ndarray  # int32 s4 log2 codes; conv [K, Cin, Cout] / FC [Cin, Cout]
    bias: np.ndarray  # int32, 14-bit range
    out_shift: int  # arithmetic right shift at the OPE (>= 0)
    dilation: int = 1
    relu: bool = True
    res_shift: Optional[int] = None  # signed residual rescale; None = no residual
    # Optional 1x1 residual conv (u4 output at the block-input shift).
    res_codes: Optional[np.ndarray] = None
    res_bias: Optional[np.ndarray] = None
    res_out_shift: Optional[int] = None


@dataclasses.dataclass
class QuantizedModel:
    """Bit-exact integer model: what gets exported to rust + HLO."""

    cfg: TCNConfig
    in_shift: int  # u4 input quantizer shift (real -> q)
    layers: list  # flat list of QLayer, two per block
    embed: QLayer
    head: Optional[QLayer]
    embed_shift: int  # u4 shift of the embedding output
    act_shifts: list  # per-tensor activation shifts (inspection/debug)

    def total_code_count(self) -> int:
        n = sum(l.codes.size + l.bias.size for l in self.layers)
        n += sum(
            l.res_codes.size + l.res_bias.size
            for l in self.layers
            if l.res_codes is not None
        )
        n += self.embed.codes.size + self.embed.bias.size
        if self.head is not None:
            n += self.head.codes.size + self.head.bias.size
        return n


def _derive(e_out_cal, e_in, g):
    """OPE shift >= 0; bump e_out if calibration asked for a finer grid."""
    shift = max(0, e_out_cal - e_in - g)
    return shift, e_in + g + shift


def _q_bias(b, scale_exp):
    return np.clip(
        np.round(np.asarray(b) / 2.0**scale_exp), ql.BIAS_MIN, ql.BIAS_MAX
    ).astype(np.int32)


def quantize_model(params, qcfg, cfg: TCNConfig) -> QuantizedModel:
    """Fold BN, encode weights to log2 codes, derive the integer shift schedule."""
    p = jax.tree_util.tree_map(np.asarray, params)
    layers = []
    e_in = int(qcfg["in_shift"])
    act_shifts = [e_in]
    for bi, block in enumerate(p["blocks"]):
        d = 2**bi
        lq = qcfg["blocks"][bi]
        e_blk = e_in
        # conv1
        w1, b1 = _folded_np(block["conv1"])
        g1 = int(np.log2(lq["conv1"]["w_scale"]))
        s1, e1 = _derive(int(lq["conv1"]["act_shift"]), e_in, g1)
        layers.append(QLayer(
            codes=np.asarray(ql.log2_encode_float(w1, 2.0**g1)),
            bias=_q_bias(b1, e_in + g1), out_shift=s1, dilation=d, relu=True,
        ))
        act_shifts.append(e1)
        # optional 1x1 residual conv: u4 output back at the block-input shift
        res_codes = res_bias = None
        res_out_shift = None
        if "res" in block:
            gr = min(int(np.log2(lq["res"]["w_scale"])), 0)  # force shift >= 0
            res_codes = np.asarray(ql.log2_encode_float(block["res"]["w"], 2.0**gr))
            res_bias = _q_bias(block["res"]["b"], e_blk + gr)
            res_out_shift = -gr  # back to e_blk scale: e_blk - (e_blk + gr)
        # conv2: residual enters the OPE at accumulator scale 2^(e1+g2)
        w2, b2 = _folded_np(block["conv2"])
        g2 = int(np.log2(lq["conv2"]["w_scale"]))
        s2, e2 = _derive(int(lq["out_shift_act"]), e1, g2)
        layers.append(QLayer(
            codes=np.asarray(ql.log2_encode_float(w2, 2.0**g2)),
            bias=_q_bias(b2, e1 + g2), out_shift=s2, dilation=d, relu=True,
            res_shift=e_blk - (e1 + g2),
            res_codes=res_codes, res_bias=res_bias, res_out_shift=res_out_shift,
        ))
        act_shifts.append(e2)
        e_in = e2
    # embedding FC
    ge = int(np.log2(qcfg["embed"]["w_scale"]))
    se, e_emb = _derive(int(qcfg["embed"]["act_shift"]), e_in, ge)
    embed = QLayer(
        codes=np.asarray(ql.log2_encode_float(p["embed"]["w"], 2.0**ge)),
        bias=_q_bias(p["embed"]["b"], e_in + ge), out_shift=se, relu=True,
    )
    head = None
    if "head" in p:
        gh = int(np.log2(qcfg["head"]["w_scale"]))
        head = QLayer(
            codes=np.asarray(ql.log2_encode_float(p["head"]["w"], 2.0**gh)),
            bias=_q_bias(p["head"]["b"], e_emb + gh), out_shift=0, relu=False,
        )
    return QuantizedModel(
        cfg=cfg, in_shift=int(qcfg["in_shift"]), layers=layers, embed=embed,
        head=head, embed_shift=e_emb, act_shifts=act_shifts,
    )


def _folded_np(conv):
    bn = conv["bn"]
    g = np.asarray(bn["gamma"]) / np.sqrt(np.asarray(bn["var"]) + 1e-5)
    return np.asarray(conv["w"]) * g, (np.asarray(conv["b"]) - np.asarray(bn["mean"])) * g + np.asarray(bn["beta"])


# ---------------------------------------------------------------------------
# Integer forward (bit-exact; oracle- or Pallas-backed)
# ---------------------------------------------------------------------------

def int_forward(qm: QuantizedModel, x_q, use_pallas: bool = False, with_head: bool = True):
    """Bit-exact integer forward: u4 input [T, Cin] -> u4 embedding or logits.

    The same computation the rust golden model and the cycle simulator
    perform; ``use_pallas=True`` swaps the oracle for the Pallas kernels
    (identical numerics; the variant ``aot.py`` lowers to HLO).
    """
    if use_pallas:
        from .kernels.dilated_conv import dilated_conv

        def run_conv(x, codes, bias, out_shift, dilation, relu, residual, res_shift):
            return dilated_conv(
                x, jnp.asarray(codes), jnp.asarray(bias), out_shift,
                codes.shape[0], dilation=dilation, relu=relu,
                residual=residual, res_shift=res_shift,
            )
    else:
        def run_conv(x, codes, bias, out_shift, dilation, relu, residual, res_shift):
            return kref.dilated_conv_ref(
                x, jnp.asarray(codes), jnp.asarray(bias), out_shift,
                dilation=dilation, relu=relu, residual=residual, res_shift=res_shift,
            )

    h = jnp.asarray(x_q, jnp.int32)
    for bi in range(qm.cfg.n_blocks):
        l1, l2 = qm.layers[2 * bi], qm.layers[2 * bi + 1]
        blk_in = h
        h = run_conv(h, l1.codes, l1.bias, l1.out_shift, l1.dilation, True, None, 0)
        res = blk_in
        if l2.res_codes is not None:
            res = run_conv(
                blk_in, l2.res_codes, l2.res_bias, l2.res_out_shift, 1, True, None, 0
            )
        # Signed residual rescale into the accumulator domain.
        rs = l2.res_shift or 0
        if rs < 0:
            res, rs = jnp.right_shift(jnp.asarray(res, jnp.int32), -rs), 0
        h = run_conv(h, l2.codes, l2.bias, l2.out_shift, l2.dilation, True, res, rs)
    last = h[-1:, :]  # [1, C]
    emb = run_conv(
        last, qm.embed.codes[None], qm.embed.bias, qm.embed.out_shift, 1, True, None, 0
    )[0]
    if with_head and qm.head is not None:
        return kref.fc_ref(emb, jnp.asarray(qm.head.codes), jnp.asarray(qm.head.bias))
    return emb


def quantize_input(x, qm: QuantizedModel):
    """Real-valued input [T, Cin] -> u4 codes at the model's input shift."""
    return np.asarray(ql.u4_encode(jnp.asarray(x), qm.in_shift))
