"""Training drivers (build-time only): meta-train the Omniglot embedder,
train the two KWS classifiers, run the QAT phase, write checkpoints.

The paper trains FP32 first, then runs Brevitas QAT from the best FP32
checkpoint with BN folded (§IV-A); we mirror that with our own JAX QAT.
Budgets are modest by default so ``make artifacts`` stays in CI territory;
set ``CHAMELEON_FULL=1`` for longer runs.
"""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from . import datasets as D
from . import io_json
from . import model as M
from . import protonet as P

FULL = os.environ.get("CHAMELEON_FULL", "0") == "1"
CKPT_DIR = os.environ.get("CHAMELEON_CKPT_DIR", os.path.join(os.path.dirname(__file__), "..", "..", "checkpoints"))


def _budget(small, full):
    return full if FULL else small


# ---------------------------------------------------------------------------
# Omniglot FSL embedder (meta-training, paper Table I / Fig. 15)
# ---------------------------------------------------------------------------

# Meta-train/meta-test class split (Vinyals-style: disjoint class sets).
OMNIGLOT_CLASSES = 400
OMNIGLOT_TRAIN_CLASSES = np.arange(0, 300)
OMNIGLOT_TEST_CLASSES = np.arange(300, 400)


def omniglot_dataset():
    return D.SyntheticOmniglot(OMNIGLOT_CLASSES)


def train_omniglot(cfg: M.TCNConfig = M.OMNIGLOT_CFG, seed: int = 0, verbose=True):
    """FP32 meta-training + QAT finetune; returns (params, qcfg, logs)."""
    ds = omniglot_dataset()
    params = M.init_params(cfg, seed=seed)
    episodes = _budget(280, 1500)
    if verbose:
        print(f"[train] omniglot FP32 meta-training: {episodes} episodes, "
              f"{cfg.param_count()} params, RF {cfg.receptive_field}")
    params, log = P.meta_train(
        params, ds, cfg, episodes=episodes, n_way=5, k_shot=5, n_query=5,
        lr=2e-3, seed=seed, class_pool=OMNIGLOT_TRAIN_CLASSES, verbose=verbose,
        log_every=20,
    )
    # Calibrate on a held-out support batch, then QAT finetune.
    rng = np.random.default_rng(seed + 1)
    sup, qry, _ = ds.episode(rng, 8, 5, 2, class_pool=OMNIGLOT_TRAIN_CLASSES)
    x_cal = jnp.asarray(sup.reshape(-1, cfg.seq_len, cfg.in_channels))
    qcfg = M.calibrate(params, x_cal, cfg)
    qat_eps = _budget(120, 500)
    if verbose:
        print(f"[train] omniglot QAT finetune: {qat_eps} episodes")
    params, qat_log = P.meta_train(
        params, ds, cfg, episodes=qat_eps, n_way=5, k_shot=5, n_query=5,
        lr=5e-4, seed=seed + 2, qat_qcfg=qcfg, class_pool=OMNIGLOT_TRAIN_CLASSES,
        verbose=verbose, log_every=20,
    )
    log.steps += [s + episodes for s in qat_log.steps]
    log.losses += qat_log.losses
    log.accs += qat_log.accs
    return params, qcfg, log


# ---------------------------------------------------------------------------
# KWS classifiers (supervised, paper Fig. 12/16/17, Table II)
# ---------------------------------------------------------------------------

def _kws_dataset(view: str):
    return D.SyntheticSpeechCommands(), view


def train_kws(cfg: M.TCNConfig, view: str, seed: int = 0, verbose=True):
    """Cross-entropy training of the TCN+head; returns (params, qcfg, log)."""
    ds, view = _kws_dataset(view)
    params = M.init_params(cfg, seed=seed)
    steps = _budget(240, 1200)
    batch = 24 if view == "mfcc" else 10
    lr = 2e-3

    def loss_fn(p, x, y):
        logits, new_p = M.float_forward(p, x, cfg, train=True, with_head=True)
        logp = jax.nn.log_softmax(logits, -1)
        loss = -jnp.mean(logp[jnp.arange(y.shape[0]), y])
        acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
        return loss, (acc, new_p)

    @jax.jit
    def step(p, opt, x, y):
        (loss, (acc, new_p)), g = jax.value_and_grad(loss_fn, has_aux=True)(p, x, y)
        new_p, opt = P.adam_update(new_p, g, opt, lr=lr)
        return new_p, opt, loss, acc

    rng = np.random.default_rng(seed)
    opt = P.adam_init(params)
    log = P.MetaTrainLog([], [], [])
    if verbose:
        print(f"[train] kws_{view} FP32: {steps} steps x batch {batch}, "
              f"{cfg.param_count()} params, RF {cfg.receptive_field}")
    for s in range(steps):
        x, y = ds.batch(rng, batch, view=view)
        params, opt, loss, acc = step(params, opt, jnp.asarray(x), jnp.asarray(y))
        if s % 20 == 0 or s == steps - 1:
            log.steps.append(s)
            log.losses.append(float(loss))
            log.accs.append(float(acc))
            if verbose:
                print(f"  step {s:4d}  loss {float(loss):.4f}  acc {float(acc):.3f}")
    # Calibrate + QAT finetune.
    x_cal, _ = ds.fixed_split(4, view, base=500)
    qcfg = M.calibrate(params, jnp.asarray(x_cal), cfg)
    qat_steps = _budget(100, 400)

    def qat_loss(p, x, y):
        logits = M.qat_forward(p, x, cfg, qcfg, with_head=True)
        logp = jax.nn.log_softmax(logits, -1)
        loss = -jnp.mean(logp[jnp.arange(y.shape[0]), y])
        acc = jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))
        return loss, acc

    @jax.jit
    def qat_step(p, opt, x, y):
        (loss, acc), g = jax.value_and_grad(qat_loss, has_aux=True)(p, x, y)
        p, opt = P.adam_update(p, g, opt, lr=3e-4)
        return p, opt, loss, acc

    if verbose:
        print(f"[train] kws_{view} QAT: {qat_steps} steps")
    opt = P.adam_init(params)
    for s in range(qat_steps):
        x, y = ds.batch(rng, batch, view=view)
        params, opt, loss, acc = qat_step(params, opt, jnp.asarray(x), jnp.asarray(y))
        if s % 20 == 0 or s == qat_steps - 1:
            log.steps.append(steps + s)
            log.losses.append(float(loss))
            log.accs.append(float(acc))
            if verbose:
                print(f"  qat step {s:4d}  loss {float(loss):.4f}  acc {float(acc):.3f}")
    return params, qcfg, log


# ---------------------------------------------------------------------------
# Checkpoint orchestration
# ---------------------------------------------------------------------------

def ensure_checkpoint(name: str, verbose=True):
    """Train-if-missing; returns (params, qcfg, log). Deterministic seeds."""
    path = os.path.join(CKPT_DIR, f"{name}.ckpt.json")
    if os.path.exists(path):
        params, qcfg, logblob = io_json.load_checkpoint(path)
        log = P.MetaTrainLog(**logblob) if logblob else None
        if verbose:
            print(f"[train] loaded checkpoint {path}")
        return params, qcfg, log
    cfg = M.MODEL_ZOO[name]
    if name == "omniglot_fsl":
        params, qcfg, log = train_omniglot(cfg, verbose=verbose)
    elif name == "kws_mfcc":
        params, qcfg, log = train_kws(cfg, "mfcc", verbose=verbose)
    elif name == "kws_raw":
        params, qcfg, log = train_kws(cfg, "raw", verbose=verbose)
    else:
        raise KeyError(name)
    io_json.save_checkpoint(path, params, qcfg, log)
    if verbose:
        print(f"[train] saved checkpoint {path}")
    return params, qcfg, log


if __name__ == "__main__":
    import sys

    names = sys.argv[1:] or list(M.MODEL_ZOO)
    for n in names:
        ensure_checkpoint(n)
