"""JSON (de)serialisation for checkpoints and the rust interchange format.

The rust side has no serde in this environment, so the interchange format is
deliberately plain JSON with flat integer arrays + explicit shapes; the
hand-rolled parser in ``rust/src/util/json.rs`` reads exactly this.
"""

from __future__ import annotations

import dataclasses
import json
import os

import jax
import numpy as np

from . import model as M


# ---------------------------------------------------------------------------
# Float checkpoints (python-only)
# ---------------------------------------------------------------------------

def _tree_to_jsonable(tree):
    if isinstance(tree, dict):
        return {k: _tree_to_jsonable(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [_tree_to_jsonable(v) for v in tree]
    arr = np.asarray(tree)
    return {"__nd__": arr.tolist(), "shape": list(arr.shape)}


def _tree_from_jsonable(obj):
    if isinstance(obj, dict) and "__nd__" in obj:
        return np.asarray(obj["__nd__"], np.float32).reshape(obj["shape"])
    if isinstance(obj, dict):
        return {k: _tree_from_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, list):
        return [_tree_from_jsonable(v) for v in obj]
    return obj


def save_checkpoint(path, params, qcfg=None, log=None):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    blob = {"params": _tree_to_jsonable(jax.tree_util.tree_map(np.asarray, params))}
    if qcfg is not None:
        blob["qcfg"] = qcfg
    if log is not None:
        blob["log"] = {"steps": log.steps, "losses": log.losses, "accs": log.accs}
    with open(path, "w") as f:
        json.dump(blob, f)


def load_checkpoint(path):
    with open(path) as f:
        blob = json.load(f)
    params = jax.tree_util.tree_map(
        lambda a: np.asarray(a), _tree_from_jsonable(blob["params"])
    )
    return params, blob.get("qcfg"), blob.get("log")


# ---------------------------------------------------------------------------
# Quantized-model interchange (read by rust/src/model)
# ---------------------------------------------------------------------------

def _qlayer_json(l: M.QLayer):
    d = {
        "codes": np.asarray(l.codes).reshape(-1).tolist(),
        "codes_shape": list(np.asarray(l.codes).shape),
        "bias": np.asarray(l.bias).reshape(-1).tolist(),
        "out_shift": int(l.out_shift),
        "dilation": int(l.dilation),
        "relu": bool(l.relu),
        "res_shift": None if l.res_shift is None else int(l.res_shift),
    }
    if l.res_codes is not None:
        d["res_codes"] = np.asarray(l.res_codes).reshape(-1).tolist()
        d["res_codes_shape"] = list(np.asarray(l.res_codes).shape)
        d["res_bias"] = np.asarray(l.res_bias).reshape(-1).tolist()
        d["res_out_shift"] = int(l.res_out_shift)
    else:
        d["res_codes"] = None
        d["res_codes_shape"] = None
        d["res_bias"] = None
        d["res_out_shift"] = None
    return d


def save_quantized_model(path, qm: M.QuantizedModel):
    cfg = qm.cfg
    blob = {
        "name": cfg.name,
        "in_channels": cfg.in_channels,
        "seq_len": cfg.seq_len,
        "channels": list(cfg.channels),
        "kernel_size": cfg.kernel_size,
        "embed_dim": cfg.embed_dim,
        "n_classes": cfg.n_classes,
        "receptive_field": cfg.receptive_field,
        "param_count": cfg.param_count(),
        "in_shift": int(qm.in_shift),
        "embed_shift": int(qm.embed_shift),
        "act_shifts": [int(s) for s in qm.act_shifts],
        "layers": [_qlayer_json(l) for l in qm.layers],
        "embed": _qlayer_json(qm.embed),
        "head": None if qm.head is None else _qlayer_json(qm.head),
    }
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(blob, f)


def save_vectors(path, cases):
    """Test vectors: list of dicts with flat int lists (+ shapes)."""
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump({"cases": cases}, f)
