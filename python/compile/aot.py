"""AOT compile path: lower the integer (Pallas-backed) TCN graphs to HLO
text and export the quantized-model interchange + test vectors for rust.

HLO *text* (NOT ``.serialize()``) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
XLA (xla_extension 0.5.1) rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per model in the zoo:

    artifacts/<name>.hlo.txt       -- u4 input [T, Cin] -> (embedding,) or
                                      (embedding, logits) integer graph
    artifacts/<name>.model.json    -- quantized weights + shift schedule
    artifacts/<name>.vectors.json  -- bit-exact test vectors for rust
    artifacts/manifest.json        -- inventory + quick eval metrics

Python runs ONCE; the rust binary is self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import datasets as D
from . import io_json
from . import model as M
from . import protonet as P
from . import train as T


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # CRITICAL: default printing elides large constants as a literal
    # "{...}", which the xla_extension 0.5.1 text parser silently turns
    # into garbage weights. Print them in full.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # New-style metadata attributes (source_end_line etc.) are rejected by
    # the 0.5.1 parser; strip metadata entirely.
    opts.print_metadata = False
    return comp.as_hlo_module().to_string(opts)


def lower_model(qm: M.QuantizedModel, use_pallas: bool = True) -> str:
    """Lower the bit-exact integer forward to HLO text.

    The Pallas kernels (interpret=True) lower into the same HLO module, so
    the artifact the rust runtime executes is the L1 kernel inside the L2
    graph — no python on the request path.
    """
    cfg = qm.cfg

    def fn(x_q):
        emb = M.int_forward(qm, x_q, use_pallas=use_pallas, with_head=False)
        if qm.head is not None:
            from .kernels import ref as kref

            logits = kref.fc_ref(emb, jnp.asarray(qm.head.codes), jnp.asarray(qm.head.bias))
            return emb, logits
        return (emb,)

    spec = jax.ShapeDtypeStruct((cfg.seq_len, cfg.in_channels), jnp.int32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def make_vectors(qm: M.QuantizedModel, inputs, with_layer_sums: bool = True):
    """Bit-exact test vectors pinning python and rust to the same numbers."""
    cases = []
    for xq in inputs:
        emb = np.asarray(M.int_forward(qm, xq, with_head=False))
        case = {
            "input": np.asarray(xq).reshape(-1).tolist(),
            "input_shape": list(np.asarray(xq).shape),
            "embedding": emb.tolist(),
        }
        if qm.head is not None:
            from .kernels import ref as kref

            logits = kref.fc_ref(
                jnp.asarray(emb), jnp.asarray(qm.head.codes), jnp.asarray(qm.head.bias)
            )
            case["logits"] = np.asarray(logits).tolist()
        if with_layer_sums:
            case["layer_sums"] = layer_output_sums(qm, xq)
        cases.append(case)
        with_layer_sums = False  # layer sums only for the first case
    return cases


def layer_output_sums(qm: M.QuantizedModel, xq):
    """Per-layer output checksums (sum of all activations) for debugging."""
    from .kernels import ref as kref

    sums = []
    h = jnp.asarray(xq, jnp.int32)
    for bi in range(qm.cfg.n_blocks):
        l1, l2 = qm.layers[2 * bi], qm.layers[2 * bi + 1]
        blk_in = h
        h = kref.dilated_conv_ref(h, jnp.asarray(l1.codes), jnp.asarray(l1.bias), l1.out_shift, dilation=l1.dilation)
        sums.append(int(jnp.sum(h)))
        res = blk_in
        if l2.res_codes is not None:
            res = kref.dilated_conv_ref(blk_in, jnp.asarray(l2.res_codes), jnp.asarray(l2.res_bias), l2.res_out_shift, dilation=1)
        rs = l2.res_shift or 0
        if rs < 0:
            res, rs = jnp.right_shift(jnp.asarray(res, jnp.int32), -rs), 0
        h = kref.dilated_conv_ref(h, jnp.asarray(l2.codes), jnp.asarray(l2.bias), l2.out_shift, dilation=l2.dilation, residual=res, res_shift=rs)
        sums.append(int(jnp.sum(h)))
    return sums


def build_one(name: str, out_dir: str, use_pallas: bool = True, verbose=True):
    cfg = M.MODEL_ZOO[name]
    params, qcfg, log = T.ensure_checkpoint(name, verbose=verbose)
    qm = M.quantize_model(params, qcfg, cfg)

    # Pallas/oracle parity check on one input before anything is written.
    if name == "omniglot_fsl":
        ds = T.omniglot_dataset()
        sample_inputs = [M.quantize_input(ds.sample(c, 0), qm) for c in (0, 301)]
    else:
        ds = D.SyntheticSpeechCommands()
        view = "mfcc" if name == "kws_mfcc" else "raw"
        sample_inputs = [M.quantize_input(ds.sample(c, 0, view), qm) for c in (0, 11)]
    ref_emb = np.asarray(M.int_forward(qm, sample_inputs[0], with_head=False))
    pal_emb = np.asarray(M.int_forward(qm, sample_inputs[0], use_pallas=True, with_head=False))
    assert (ref_emb == pal_emb).all(), f"pallas/oracle mismatch for {name}"

    t0 = time.time()
    hlo = lower_model(qm, use_pallas=use_pallas)
    if verbose:
        print(f"[aot] {name}: lowered to HLO in {time.time()-t0:.1f}s ({len(hlo)} chars)")
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(hlo)
    io_json.save_quantized_model(os.path.join(out_dir, f"{name}.model.json"), qm)
    io_json.save_vectors(
        os.path.join(out_dir, f"{name}.vectors.json"), make_vectors(qm, sample_inputs)
    )
    entry = {
        "name": name,
        "hlo": f"{name}.hlo.txt",
        "model": f"{name}.model.json",
        "vectors": f"{name}.vectors.json",
        "params": cfg.param_count(),
        "receptive_field": cfg.receptive_field,
        "seq_len": cfg.seq_len,
        "in_channels": cfg.in_channels,
        "embed_dim": cfg.embed_dim,
        "n_classes": cfg.n_classes,
    }
    if log is not None:
        entry["train_log"] = {"steps": log.steps, "losses": log.losses, "accs": log.accs}
    return entry


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--models", nargs="*", default=list(M.MODEL_ZOO))
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the oracle graph instead of the Pallas kernels")
    args = ap.parse_args()
    out_dir = args.out if os.path.isabs(args.out) else os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)
    # Merge with any existing manifest so partial rebuilds keep other models.
    manifest_path = os.path.join(out_dir, "manifest.json")
    existing = {}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            existing = {m["name"]: m for m in json.load(f).get("models", [])}
    for name in args.models:
        existing[name] = build_one(name, out_dir, use_pallas=not args.no_pallas)
    manifest = {"models": [existing[k] for k in sorted(existing)]}
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] wrote {len(manifest['models'])} models to {out_dir}")


if __name__ == "__main__":
    main()
