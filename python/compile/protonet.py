"""Prototypical-network meta-learning (paper §II-A, §III-A) + Eq. 3-8.

Implements:

* episodic meta-training of the TCN embedder with the prototypical loss
  (squared-L2 distances, softmax over negated distances) — the off-chip
  ``meta-training`` phase of the paper;
* the PN -> FC reformulation, both in float (Eq. 6) and in the chip's
  quantized log2 form (Eq. 8 + the po2 pre-shift detailed in DESIGN.md);
* a hand-rolled Adam (no optax in this environment).
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import quantlib as ql

# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------


def adam_init(params):
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": z, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m_: m_ / (1 - b1 ** t.astype(jnp.float32)), m)
    vh = jax.tree_util.tree_map(lambda v_: v_ / (1 - b2 ** t.astype(jnp.float32)), v)
    new = jax.tree_util.tree_map(lambda p, m_, v_: p - lr * m_ / (jnp.sqrt(v_) + eps), params, mh, vh)
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Prototypical loss
# ---------------------------------------------------------------------------


def proto_loss(sup_emb, qry_emb, n_way, k_shot, n_query, proto_quant_scale=None):
    """Squared-L2 prototypical loss + accuracy.

    ``sup_emb`` [N*k, V] grouped class-major; ``qry_emb`` [N*q, V] likewise.
    ``proto_quant_scale``: when set (QAT), prototypes are fake-quantized to
    the log2 grid at that scale — matching the chip's Eq. 8 deployment where
    prototype weights are s4 log2 codes (paper §IV-A: "prototypes are
    quantized using 4-bit signed log2 quantization").
    """
    protos = sup_emb.reshape(n_way, k_shot, -1).mean(axis=1)  # [N, V]
    if proto_quant_scale is not None:
        protos = ql.ste_log2(protos, proto_quant_scale)
    d2 = jnp.sum((qry_emb[:, None, :] - protos[None, :, :]) ** 2, axis=-1)  # [Nq, N]
    logits = -d2
    labels = jnp.repeat(jnp.arange(n_way), n_query)
    logp = jax.nn.log_softmax(logits, axis=-1)
    loss = -jnp.mean(logp[jnp.arange(labels.shape[0]), labels])
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32))
    return loss, acc


# ---------------------------------------------------------------------------
# Meta-training loop
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class MetaTrainLog:
    steps: list
    losses: list
    accs: list


def make_episode_step(cfg: M.TCNConfig, n_way, k_shot, n_query, lr, qat_qcfg=None):
    """Build a jitted one-episode update closure (float or QAT graph)."""

    def loss_fn(params, sup, qry):
        if qat_qcfg is None:
            sup_emb, new_params = M.float_forward(params, sup, cfg, train=True, with_head=False)
            qry_emb, _ = M.float_forward(new_params, qry, cfg, train=True, with_head=False)
            pq_scale = None
        else:
            sup_emb = M.qat_forward(params, sup, cfg, qat_qcfg, with_head=False)
            qry_emb = M.qat_forward(params, qry, cfg, qat_qcfg, with_head=False)
            new_params = params
            # Prototype weights deploy as log2 codes on the u4 embedding
            # grid; fold that quantizer into the QAT loss.
            pq_scale = 2.0 ** qat_qcfg["embed"]["act_shift"]
        loss, acc = proto_loss(sup_emb, qry_emb, n_way, k_shot, n_query, proto_quant_scale=pq_scale)
        return loss, (acc, new_params)

    @jax.jit
    def step(params, opt, sup, qry):
        (loss, (acc, new_params)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, sup, qry)
        # BN running stats come back through new_params; apply Adam on top.
        new_params, opt = adam_update(new_params, grads, opt, lr=lr)
        return new_params, opt, loss, acc

    return step


def meta_train(
    params, dataset, cfg: M.TCNConfig, *, episodes=200, n_way=5, k_shot=5,
    n_query=5, lr=1e-3, seed=0, qat_qcfg=None, log_every=10, class_pool=None,
    verbose=True,
):
    """Episodic prototypical meta-training; returns (params, MetaTrainLog)."""
    rng = np.random.default_rng(seed)
    step = make_episode_step(cfg, n_way, k_shot, n_query, lr, qat_qcfg)
    opt = adam_init(params)
    log = MetaTrainLog([], [], [])
    for ep in range(episodes):
        sup, qry, _ = dataset.episode(rng, n_way, k_shot, n_query, class_pool=class_pool)
        sup = jnp.asarray(sup.reshape(n_way * k_shot, *sup.shape[2:]))
        qry = jnp.asarray(qry.reshape(n_way * n_query, *qry.shape[2:]))
        params, opt, loss, acc = step(params, opt, sup, qry)
        if ep % log_every == 0 or ep == episodes - 1:
            log.steps.append(ep)
            log.losses.append(float(loss))
            log.accs.append(float(acc))
            if verbose:
                print(f"  episode {ep:4d}  loss {float(loss):.4f}  acc {float(acc):.3f}")
    return params, log


# ---------------------------------------------------------------------------
# PN -> FC conversion
# ---------------------------------------------------------------------------


def pn_to_fc_float(sup_emb, n_way, k_shot):
    """Eq. 6: float prototypes -> equivalent FC (W [V, N], b [N]).

    Emits *negated* distance terms so downstream argmax(logits) equals
    argmin(distance): ``logit_j = W_j . x - b_j`` with ``W_j = s^j``,
    ``b_j = (1/2k) sum_i (s_i^j)^2`` (then logits scaled by 2/k are
    monotone in -D^2).
    """
    s = sup_emb.reshape(n_way, k_shot, -1).sum(axis=1)  # [N, V]
    w = s.T  # [V, N]
    b = -(s**2).sum(axis=1) / (2.0 * k_shot)  # [N]
    return w, b


def classify_float_fc(emb, w, b):
    return jnp.argmax(emb @ w + b, axis=-1)


def proto_preshift(k_shot: int) -> int:
    """po2 approximation of the class-mean: s >> ceil(log2 k) ~= prototype."""
    return max(0, math.ceil(math.log2(k_shot))) if k_shot > 1 else 0


def pn_to_fc_quant(sup_emb_q, n_way, k_shot):
    """Eq. 8: quantized prototypes -> log2 FC codes + 14-bit biases.

    ``sup_emb_q`` int32 [N*k, V] u4 embeddings (class-major). The per-class
    embedding sum is divided by the shot count (round-half-up; the paper
    uses the po2 pre-shift ``>> ceil(log2 k)`` — identical for po2 k, see
    rust ``ProtoAccumulator::extract`` for the deviation rationale) and
    log2-encoded, so every weight is a shift; the bias is
    ``-(1/2) sum_i shat_i^2`` computed purely with shifts (``2^(2e)``),
    saturated to the 14-bit bias grid.

    Returns (codes [V, N] int32, bias [N] int32).
    """
    sup = np.asarray(sup_emb_q, np.int64).reshape(n_way, k_shot, -1)
    s = sup.sum(axis=1)  # [N, V], values in 0..15k
    s_hat = (2 * s + k_shot) // (2 * k_shot)  # rounded mean
    codes = np.asarray(ql.log2_encode_int(jnp.asarray(s_hat, jnp.int32)))  # [N, V]
    dec = np.asarray(ql.log2_decode(jnp.asarray(codes)), np.int64)  # exact 2^e values
    # b_j = -(1/2) sum dec^2 ; dec^2 = 1 << (2e) -- shifts only on chip.
    b = -(dec.astype(np.int64) ** 2).sum(axis=1) >> 1
    b = np.clip(b, ql.BIAS_MIN, ql.BIAS_MAX).astype(np.int32)
    return codes.T.astype(np.int32), b


def classify_quant_fc(emb_q, codes, bias):
    """On-chip classification: argmax over the saturated FC logits."""
    from .kernels import ref as kref

    logits = kref.fc_ref(jnp.asarray(emb_q, jnp.int32), jnp.asarray(codes), jnp.asarray(bias))
    return int(jnp.argmax(logits)), np.asarray(logits)


# ---------------------------------------------------------------------------
# End-to-end FSL / CL evaluation harnesses (python-side reference; the rust
# benches re-run the same protocol through the simulator)
# ---------------------------------------------------------------------------


def eval_fsl_float(params, dataset, cfg, *, n_way, k_shot, n_tasks=100, n_query=5, seed=1, class_pool=None):
    """Float PN baseline accuracy (the 'FP32 embedder' upper bound)."""
    rng = np.random.default_rng(seed)
    fwd = jax.jit(lambda p, x: M.float_forward(p, x, cfg, train=False, with_head=False)[0])
    accs = []
    for _ in range(n_tasks):
        sup, qry, _ = dataset.episode(rng, n_way, k_shot, n_query, class_pool=class_pool)
        se = fwd(params, jnp.asarray(sup.reshape(n_way * k_shot, *sup.shape[2:])))
        qe = fwd(params, jnp.asarray(qry.reshape(n_way * n_query, *qry.shape[2:])))
        w, b = pn_to_fc_float(se, n_way, k_shot)
        pred = classify_float_fc(qe, w, b)
        labels = jnp.repeat(jnp.arange(n_way), n_query)
        accs.append(float(jnp.mean((pred == labels).astype(jnp.float32))))
    return float(np.mean(accs)), float(1.96 * np.std(accs) / np.sqrt(len(accs)))


def eval_fsl_quant(qm, dataset, *, n_way, k_shot, n_tasks=20, n_query=5, seed=1, class_pool=None):
    """Fully quantized end-to-end FSL (the chip's protocol, python oracle)."""
    rng = np.random.default_rng(seed)
    accs = []
    for _ in range(n_tasks):
        sup, qry, _ = dataset.episode(rng, n_way, k_shot, n_query, class_pool=class_pool)
        se = np.stack([
            np.asarray(M.int_forward(qm, M.quantize_input(s, qm), with_head=False))
            for s in sup.reshape(n_way * k_shot, *sup.shape[2:])
        ])
        codes, bias = pn_to_fc_quant(se, n_way, k_shot)
        correct = 0
        total = 0
        for ci in range(n_way):
            for q in qry[ci]:
                emb = np.asarray(M.int_forward(qm, M.quantize_input(q, qm), with_head=False))
                pred, _ = classify_quant_fc(emb, codes, bias)
                correct += int(pred == ci)
                total += 1
        accs.append(correct / total)
    return float(np.mean(accs)), float(1.96 * np.std(accs) / np.sqrt(len(accs)))
