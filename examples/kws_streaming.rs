//! END-TO-END DRIVER: streaming keyword spotting through the full system.
//!
//! This is the repository's end-to-end validation (DESIGN.md): a real
//! small workload — a continuous synthetic-audio stream built from the
//! exported test utterances — driven through the streaming coordinator
//! backed by engine replicas running the AOT-compiled Pallas/JAX artifact
//! (PJRT) and the cycle-level chip simulator side by side. Reports
//! accuracy, host latency/throughput, and the chip-side cycle/energy
//! numbers at the paper's operating points.
//!
//! Run: `cargo run --release --example kws_streaming -- [--minutes 1]
//!       [--engine golden|sim|xla] [--workers 2] [--model kws_mfcc]`

use std::sync::Arc;
use std::time::Instant;

use chameleon::coordinator::server::EngineFactory;
use chameleon::coordinator::{AudioWindower, Coordinator, CoordinatorConfig, Engine};
use chameleon::expt;
use chameleon::runtime::{Runtime, XlaModel};
use chameleon::sim::{ArrayMode, OperatingPoint};
use chameleon::util::args::Args;
use chameleon::util::bench::{fmt_dur, fmt_power, Table};
use chameleon::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let model_name = args.get_or("model", "kws_mfcc").to_string();
    let engine_kind = args.get_or("engine", "xla").to_string();
    let workers = args.get_usize("workers", 2)?;
    let n_windows = args.get_usize("windows", 120)?;

    let dir = expt::require_artifacts()?;
    let model = Arc::new(expt::load_model(&model_name)?);
    let pool = Arc::new(expt::load_pool(&model_name)?);
    println!("end-to-end streaming KWS");
    println!("  model : {}", model.describe());
    println!("  engine: {engine_kind} x{workers} workers");

    // Coordinator with the chosen engine replicas.
    let factories: Vec<EngineFactory> = (0..workers)
        .map(|_| {
            let m = model.clone();
            let kind = engine_kind.clone();
            let dir = dir.clone();
            Box::new(move || -> anyhow::Result<Engine> {
                Ok(match kind.as_str() {
                    "golden" => Engine::golden(m),
                    "sim" => Engine::sim(m, ArrayMode::M4x4),
                    _ => {
                        let rt = Runtime::cpu()?;
                        let xm = XlaModel::load(&rt, &dir, &m)?;
                        std::mem::forget(rt);
                        Engine::xla(m, xm)
                    }
                })
            }) as EngineFactory
        })
        .collect();
    let coord = Coordinator::start(
        factories,
        CoordinatorConfig { workers, queue_depth: 64, ..Default::default() },
    )?;

    // Build a continuous stream: random utterances back to back, window =
    // one model input, hop = window (the chip classifies 1/s windows).
    let mut windower = AudioWindower::new(pool.seq_len, pool.seq_len, pool.in_channels);
    let mut rng = Rng::new(11);
    let t0 = Instant::now();
    let mut served = 0usize;
    let mut correct = 0usize;
    let mut labels = Vec::new();
    let mut host_latencies = Vec::new();
    while served < n_windows {
        // "microphone" produces one utterance worth of samples
        let class = rng.below(pool.classes as u64) as usize;
        let idx = rng.below(pool.samples_per_class as u64) as usize;
        labels.push(class);
        for window in windower.push(pool.sample(class, idx)) {
            let t = Instant::now();
            let r = coord.classify(window)?;
            host_latencies.push(t.elapsed());
            let truth = labels[served];
            correct += usize::from(r.predicted == Some(truth));
            served += 1;
            if served >= n_windows {
                break;
            }
        }
    }
    let wall = t0.elapsed();
    let snap = coord.metrics().snapshot();

    // Chip-side numbers from the simulator at the paper's operating point.
    let sim_engine = Engine::sim(model.clone(), ArrayMode::M4x4);
    let chip = sim_engine.forward(pool.sample(0, 0))?;
    let trace = chip.trace.unwrap();
    let op = if model_name == "kws_raw" {
        OperatingPoint::kws_raw()
    } else {
        OperatingPoint::kws_low_power()
    };

    let mut t = Table::new("end-to-end streaming KWS results", &["metric", "value"]);
    t.rowv(vec!["windows served".into(), served.to_string()]);
    t.rowv(vec![
        "accuracy".into(),
        format!("{:.1}% ({} / {})", 100.0 * correct as f64 / served as f64, correct, served),
    ]);
    t.rowv(vec![
        "host throughput".into(),
        format!("{:.1} windows/s", served as f64 / wall.as_secs_f64()),
    ]);
    t.rowv(vec![
        "host latency mean/p99".into(),
        format!("{:.1} / {:.1} us", snap.mean_latency_us, snap.p99_latency_us),
    ]);
    t.rowv(vec![
        "chip cycles / window".into(),
        trace.total_cycles().to_string(),
    ]);
    t.rowv(vec![
        "chip real-time clock".into(),
        format!("{:.1} kHz (1 window/s)", trace.total_cycles() as f64 / 1e3),
    ]);
    t.rowv(vec![
        "chip real-time power (model)".into(),
        format!(
            "{} @ {:.2} V ({}) — paper: 3.1 uW MFCC / 59.4 uW raw",
            fmt_power(op.power().total()),
            op.voltage,
            if op.mode == ArrayMode::M4x4 { "4x4" } else { "16x16" },
        ),
    ]);
    t.rowv(vec![
        "chip energy / window".into(),
        chameleon::util::bench::fmt_energy(op.energy(trace.total_cycles())),
    ]);
    t.rowv(vec![
        "act-mem high water".into(),
        format!("{} B (budget 2048 B)", trace.act_mem_high_water),
    ]);
    t.print();

    println!(
        "\nhost mean latency {} over {} requests ({} errors, {} rejected)",
        fmt_dur(wall / served as u32),
        snap.completed,
        snap.errors,
        snap.rejected
    );
    coord.shutdown();
    assert!(correct * 3 > served, "accuracy collapsed");
    println!("END-TO-END OK: stream -> windower -> coordinator -> {engine_kind} engine -> prediction");
    Ok(())
}
