//! On-"chip" few-shot learning on sequential Omniglot: enroll N new
//! character classes from k handwriting samples each, then classify unseen
//! queries — the paper's Fig. 6 flow, with per-step cycle/energy/latency
//! accounting from the cycle simulator.
//!
//! Run: `cargo run --release --example fsl_omniglot -- [--ways 5]
//!       [--shots 1] [--queries 5] [--tasks 3] [--mode 16]`

use std::time::Duration;

use chameleon::expt;
use chameleon::sim::{learning_cycles, ArrayMode, LearningController, OperatingPoint};
use chameleon::util::args::Args;
use chameleon::util::bench::{fmt_dur, fmt_energy, Table};
use chameleon::util::rng::Rng;
use chameleon::util::stats;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let n_way = args.get_usize("ways", 5)?;
    let k_shot = args.get_usize("shots", 1)?;
    let n_query = args.get_usize("queries", 5)?;
    let n_tasks = args.get_usize("tasks", 3)?;
    let mode = if args.get_or("mode", "16") == "4" { ArrayMode::M4x4 } else { ArrayMode::M16x16 };

    let model = expt::load_model("omniglot_fsl")?;
    let pool = expt::load_pool("omniglot")?;
    println!("on-chip FSL: {n_way}-way {k_shot}-shot, {n_tasks} tasks");
    println!("  embedder: {}", model.describe());
    println!("  pool: {} unseen character classes", pool.classes);

    let op = OperatingPoint::fsl_fast();
    let op_low = OperatingPoint::fsl_low_power();
    let mut rng = Rng::new(args.get_u64("seed", 2)?);
    let mut accs = Vec::new();
    let mut learn_cycles_per_way = 0u64;
    for task in 0..n_tasks {
        let mut lc = LearningController::new(&model, mode);
        let (_, sup, qry) = pool.episode(&mut rng, n_way, k_shot, n_query);
        for shots in &sup {
            let t = lc.learn_way(shots)?;
            learn_cycles_per_way = t.total_cycles();
            // the paper's closed-form learning latency must hold exactly
            assert_eq!(
                t.learning_overhead_cycles(),
                learning_cycles(k_shot, model.embed_dim)
            );
        }
        let mut correct = 0;
        let mut total = 0;
        for (way, queries) in qry.iter().enumerate() {
            for q in queries {
                let (pred, _) = lc.classify(q)?;
                correct += usize::from(pred == way);
                total += 1;
            }
        }
        let acc = correct as f64 / total as f64;
        println!("  task {task}: {:.1}% ({correct}/{total})", acc * 100.0);
        accs.push(acc);
    }

    let mut t = Table::new("FSL summary", &["metric", "value"]);
    t.rowv(vec![
        format!("{n_way}-way {k_shot}-shot accuracy"),
        format!("{:.1}% ± {:.1}%", 100.0 * stats::mean(&accs), 100.0 * stats::ci95(&accs)),
    ]);
    t.rowv(vec![
        "learning cycles / way (incl. embedding)".into(),
        learn_cycles_per_way.to_string(),
    ]);
    t.rowv(vec![
        "extraction-only cycles (Eq. (k+2)V/16+1)".into(),
        learning_cycles(k_shot, model.embed_dim).to_string(),
    ]);
    t.rowv(vec![
        "latency / way @100 MHz".into(),
        fmt_dur(Duration::from_secs_f64(op.seconds(learn_cycles_per_way))),
    ]);
    t.rowv(vec![
        "latency / way @100 kHz 0.625 V".into(),
        fmt_dur(Duration::from_secs_f64(op_low.seconds(learn_cycles_per_way))),
    ]);
    t.rowv(vec![
        "energy / way @1.0 V".into(),
        fmt_energy(op.energy(learn_cycles_per_way)),
    ]);
    t.rowv(vec![
        "memory / way".into(),
        format!("{} B", model.embed_dim / 2 + 2),
    ]);
    t.print();
    println!("(paper @real Omniglot: 96.8% 5w1s, 0.59 ms and 6.84 uJ per shot @100 MHz)");
    Ok(())
}
