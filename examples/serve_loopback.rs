//! Serving quickstart: start the sharded TCP server in-process on an
//! ephemeral port, then talk to it over the wire protocol with the client
//! library — learn two ways in a session, classify against them, inspect
//! health and metrics, evict. Uses the built-in demo model, so it runs on
//! a fresh checkout with no artifacts.
//!
//! Run: `cargo run --release --example serve_loopback`
//!
//! For a standalone server + load generator, use the subcommands instead:
//! `cargo run --release -- serve` and `cargo run --release -- loadgen`.

use std::sync::Arc;

use chameleon::coordinator::server::EngineFactory;
use chameleon::coordinator::Engine;
use chameleon::model::demo_tiny_kws;
use chameleon::serve::{Client, ServeConfig, Server};
use chameleon::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let model = Arc::new(demo_tiny_kws());
    println!("model: {}", model.describe());

    let cfg = ServeConfig::builder().addr("127.0.0.1:0").build()?;
    let m = model.clone();
    let server = Server::start(cfg, move |_shard, _worker| {
        let m = m.clone();
        Box::new(move || Ok(Engine::golden(m))) as EngineFactory
    })?;
    println!("server on {} ({} shards)", server.local_addr(), server.shard_count());

    let mut client = Client::connect(server.local_addr().to_string())?;
    let health = client.health()?;
    println!(
        "health: {} shards, input_len {}, embed_dim {}",
        health.shards, health.input_len, health.embed_dim
    );

    // Learn two "classes" of sequences as session 42, then classify.
    let mut rng = Rng::new(7);
    let mk = |rng: &mut Rng, lo: i64, hi: i64| -> Vec<u8> {
        (0..health.input_len as usize).map(|_| rng.range(lo, hi) as u8).collect()
    };
    let low: Vec<Vec<u8>> = (0..3).map(|_| mk(&mut rng, 0, 3)).collect();
    let high: Vec<Vec<u8>> = (0..3).map(|_| mk(&mut rng, 13, 16)).collect();
    println!("learned way {:?}", client.learn_way(42, low)?.learned_way);
    println!("learned way {:?}", client.learn_way(42, high)?.learned_way);

    let pred_low = client.classify_session(42, mk(&mut rng, 0, 3))?.predicted;
    let pred_high = client.classify_session(42, mk(&mut rng, 13, 16))?.predicted;
    println!("classify(low-ish)  -> way {pred_low:?}");
    println!("classify(high-ish) -> way {pred_high:?}");
    assert_eq!(pred_low, Some(0));
    assert_eq!(pred_high, Some(1));

    // Built-in head classification (KWS-style) works too.
    let kws = client.classify(mk(&mut rng, 0, 16))?;
    println!("built-in head -> class {:?} of {}", kws.predicted, model.n_classes.unwrap());

    println!("metrics: {}", client.metrics()?.report());
    println!("evicted session 42: {}", client.evict_session(42)?);
    server.shutdown();
    println!("OK: wire protocol round trip complete");
    Ok(())
}
