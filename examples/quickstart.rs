//! Quickstart: load the AOT artifacts, classify one spoken keyword on all
//! three engines (golden / cycle-sim / PJRT-executed Pallas graph) and
//! show they agree bit-exactly.
//!
//! Run: `cargo run --release --example quickstart`
//! (requires `make artifacts` to have been run once.)

use std::sync::Arc;

use chameleon::coordinator::Engine;
use chameleon::expt;
use chameleon::golden;
use chameleon::runtime::{Runtime, XlaModel};
use chameleon::sim::ArrayMode;

fn main() -> anyhow::Result<()> {
    let dir = expt::require_artifacts()?;
    let model = Arc::new(expt::load_model("kws_mfcc")?);
    let pool = expt::load_pool("kws_mfcc")?;
    println!("model: {}", model.describe());

    // One test utterance of the keyword "yes" (class 0).
    let class = 0usize;
    let x = pool.sample(class, 3).to_vec();
    let names = pool.class_names.as_ref().unwrap();

    let rt = Runtime::cpu()?;
    let engines = vec![
        Engine::golden(model.clone()),
        Engine::sim(model.clone(), ArrayMode::M4x4),
        Engine::xla(model.clone(), XlaModel::load(&rt, &dir, &model)?),
    ];

    let mut last_logits: Option<Vec<i32>> = None;
    for e in &engines {
        let fwd = e.forward(&x)?;
        let logits = fwd.logits.expect("kws model has a head");
        let pred = golden::argmax(&logits);
        print!("engine {:<7} -> predicted {:?}", e.name(), names[pred]);
        if let Some(t) = fwd.trace {
            print!(
                "  ({} cycles, {} MACs, {} B act mem)",
                t.total_cycles(),
                t.total_macs(),
                t.act_mem_high_water
            );
        }
        println!();
        if let Some(prev) = &last_logits {
            assert_eq!(prev, &logits, "engines must agree bit-exactly");
        }
        last_logits = Some(logits);
    }
    println!("\ntrue class: {:?} — all three engines agree bit-exactly", names[class]);
    Ok(())
}
