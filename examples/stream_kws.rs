//! END-TO-END DRIVER: incremental streaming KWS over the wire.
//!
//! Starts a loopback serve stack (sharded TCP server, built-in `tiny_kws`
//! demo model — no artifacts needed), then drives it with the protocol-v2
//! stream ops: `StreamOpen` a session, `StreamPush` a continuous synthetic
//! audio stream in ragged chunks, collect one classification decision per
//! hop-strided window, and `StreamClose`. Every decision's logits are
//! cross-checked against `golden::forward` on the corresponding window —
//! the incremental executor is bit-exact, not approximately right.
//!
//! Run: `cargo run --release --example stream_kws -- [--hop 4]
//!       [--windows 12] [--chunk 11]`

use std::sync::Arc;

use chameleon::coordinator::server::EngineFactory;
use chameleon::coordinator::Engine;
use chameleon::golden;
use chameleon::model::demo_tiny_kws;
use chameleon::serve::{Client, ServeConfig, Server};
use chameleon::util::args::Args;
use chameleon::util::bench::Table;
use chameleon::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let hop = args.get_usize("hop", 4)?;
    let n_windows = args.get_usize("windows", 12)?;
    let chunk = args.get_usize("chunk", 11)?; // deliberately ragged

    let model = Arc::new(demo_tiny_kws());
    println!("end-to-end streaming KWS over the wire");
    println!("  model : {}", model.describe());
    println!("  window: {} steps, hop {hop}, chunks of {chunk} bytes", model.seq_len);

    let m = model.clone();
    let server = Server::start(
        ServeConfig::builder().addr("127.0.0.1:0").shards(2).workers_per_shard(2).build()?,
        move |_shard, _worker| {
            let m = m.clone();
            Box::new(move || Ok(Engine::golden(m))) as EngineFactory
        },
    )?;
    let mut client = Client::connect(server.local_addr().to_string())?;

    let session = 42u64;
    let (window, hop_echo) = client.stream_open(session, hop as u32)?;
    assert_eq!(window as usize, model.seq_len);
    assert_eq!(hop_echo as usize, hop);

    // A continuous synthetic "microphone": enough samples for n_windows
    // hop-strided windows.
    let t_total = model.seq_len + (n_windows - 1) * hop;
    let mut rng = Rng::new(7);
    let stream: Vec<u8> = (0..t_total * model.in_channels).map(|_| rng.below(16) as u8).collect();

    let mut decisions = Vec::new();
    let mut pushes = 0u32;
    for part in stream.chunks(chunk) {
        decisions.extend(client.stream_push(session, part.to_vec())?);
        pushes += 1;
    }
    assert_eq!(decisions.len(), n_windows, "one decision per complete window");

    let mut t = Table::new(
        &format!("stream decisions ({pushes} pushes)"),
        &["window", "end step", "predicted", "bit-exact vs golden::forward"],
    );
    for d in &decisions {
        let start = d.window as usize * hop;
        let w = &stream[start * model.in_channels..(start + model.seq_len) * model.in_channels];
        let (_, logits) = golden::forward(&model, w)?;
        assert_eq!(Some(&d.logits), logits.as_ref(), "window {}", d.window);
        assert_eq!(d.predicted, golden::argmax(&d.logits) as u64);
        t.rowv(vec![
            d.window.to_string(),
            d.end_t.to_string(),
            d.predicted.to_string(),
            "yes".into(),
        ]);
    }
    t.print();

    let (existed, windows) = client.stream_close(session)?;
    assert!(existed);
    assert_eq!(windows, n_windows as u64);

    let metrics = client.metrics()?;
    println!("\nserver: {}", metrics.report());
    assert_eq!(metrics.stream_decisions, n_windows as u64);
    server.shutdown();
    println!(
        "END-TO-END OK: chunked stream -> wire v2 -> shard session -> incremental \
         golden executor -> {n_windows} bit-exact decisions"
    );
    Ok(())
}
