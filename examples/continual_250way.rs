//! Continual learning to 250 ways (paper Fig. 15 / Table II): one class at
//! a time, k shots each, re-evaluating accuracy over everything learned so
//! far — all on the quantized on-"chip" pipeline, with the per-way memory
//! accounting that lets Chameleon scale where fixed-array designs cap out.
//!
//! Run: `cargo run --release --example continual_250way -- [--shots 5]
//!       [--max-ways 250] [--queries 3]`

use chameleon::expt::{self, EmbedCache};
use chameleon::util::args::Args;
use chameleon::util::bench::Table;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let k_shot = args.get_usize("shots", 5)?;
    let max_ways = args.get_usize("max-ways", 250)?;
    let n_query = args.get_usize("queries", 3)?;

    let model = expt::load_model("omniglot_fsl")?;
    let pool = expt::load_pool("omniglot")?;
    println!("continual learning: up to {max_ways} ways, {k_shot} shots each");
    println!("  embedder: {}", model.describe());

    let eval_at: Vec<usize> = [2, 5, 10, 25, 50, 100, 150, 200, 250]
        .into_iter()
        .filter(|&w| w <= max_ways)
        .collect();
    let mut cache = EmbedCache::new(&model, &pool);
    let curve = expt::cl_run(&mut cache, k_shot, n_query, &eval_at, args.get_u64("seed", 4)?)?;

    let mut t = Table::new("CL accuracy vs ways", &["ways learned", "accuracy", "head memory"]);
    for (ways, acc) in &curve {
        t.rowv(vec![
            ways.to_string(),
            format!("{:.1}%", acc * 100.0),
            format!("{} B", ways * (model.embed_dim / 2 + 2)),
        ]);
    }
    t.print();

    let (final_ways, final_acc) = *curve.last().unwrap();
    let avg = expt::cl_average(&curve);
    println!(
        "\nfinal {:.1}% at {final_ways} ways, average {:.1}% \
         (paper @real Omniglot, 10-shot: 82.2% final, 89.0% avg)",
        final_acc * 100.0,
        avg * 100.0
    );
    println!(
        "head memory at {final_ways} ways: {} B — {:.2}% of the {}-B deployed model",
        final_ways * (model.embed_dim / 2 + 2),
        100.0 * (final_ways * (model.embed_dim / 2 + 2)) as f64 / (model.param_count() / 2) as f64,
        model.param_count() / 2,
    );
    assert!(final_acc > 3.0 / final_ways as f64, "must stay far above chance");
    Ok(())
}
